//! Native forward passes.
//!
//! `prefill` runs full-precision causal attention over the prompt (the
//! JAX prefill graph's twin) and streams the post-RoPE K/V into the
//! quantized cache.  `prefill_chunk` is its resumable form: one chunk of
//! a prompt at a time, attending over whatever the cache already holds
//! (quantized groups via the LUT, fp residual densely) plus the chunk's
//! own causal prefix — the primitive under the engine's chunked-prefill
//! continuous batching.  `decode_step` is the serving hot path: attention
//! scores over the quantized region come from the PolarQuant LUT
//! ([`crate::quant::lut::QkLut`]), the fp residual tail and the current
//! token are scored densely, and the value product uses the fused
//! weighted-sum kernel when values are quantized.

use std::sync::Arc;

use crate::kvcache::stream::GroupValues;
use crate::kvcache::SequenceCache;
use crate::quant::lut::{default_kernel, QkLut, ScoreKernel};
use crate::quant::value;
use crate::tensor::ops::*;

use super::config::ModelConfig;
use super::weights::Weights;

pub struct Model {
    pub cfg: ModelConfig,
    /// shared, read-only: [`Model::fork`] hands the same weights to every
    /// decode-pool worker; only the scratch below is per-thread
    pub weights: Arc<Weights>,
    freqs: Vec<f32>,
    /// the score-kernel backend every LUT built by this model uses
    /// ([`crate::quant::lut::select_kernel`]); [`Model::fork`] propagates
    /// it, so decode-pool workers inherit the engine's `--kernel` choice
    kernel: &'static dyn ScoreKernel,
    // decode-step scratch (allocation-free steady state)
    lut: QkLut,
    scores: Vec<Vec<f32>>,
    attn_out: Vec<f32>,
    x: Vec<f32>,
    xn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    o: Vec<f32>,
    ffn_gate: Vec<f32>,
    ffn_up: Vec<f32>,
    logits: Vec<f32>,
}

impl Model {
    pub fn new(cfg: ModelConfig, weights: Weights) -> Self {
        Model::from_shared(cfg, Arc::new(weights))
    }

    /// Build a model over already-shared weights (decode-pool workers).
    pub fn from_shared(cfg: ModelConfig, weights: Arc<Weights>) -> Self {
        Model::from_shared_with_kernel(cfg, weights, default_kernel())
    }

    /// [`Model::from_shared`] with an explicit [`ScoreKernel`] — the
    /// engine resolves `--kernel` once and builds/forks models through
    /// this so every LUT in the process agrees.
    pub fn from_shared_with_kernel(
        cfg: ModelConfig,
        weights: Arc<Weights>,
        kernel: &'static dyn ScoreKernel,
    ) -> Self {
        let dh = cfg.head_dim;
        let hq = cfg.q_per_kv();
        Model {
            freqs: rope_freqs(dh, cfg.rope_base),
            kernel,
            lut: QkLut::with_kernel(cfg.polar_spec(), dh, hq, kernel),
            scores: vec![Vec::new(); hq],
            attn_out: vec![0.0; cfg.n_heads * dh],
            x: vec![0.0; cfg.d_model],
            xn: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.n_heads * dh],
            k: vec![0.0; cfg.n_kv_heads * dh],
            v: vec![0.0; cfg.n_kv_heads * dh],
            o: vec![0.0; cfg.d_model],
            ffn_gate: vec![0.0; cfg.ffn],
            ffn_up: vec![0.0; cfg.ffn],
            logits: vec![0.0; cfg.vocab],
            cfg,
            weights,
        }
    }

    /// A new model sharing these weights with FRESH scratch (LUT, score
    /// and activation buffers) — what each decode-pool worker thread owns.
    /// Cost: a handful of small allocations; the weights are never copied.
    /// The score kernel carries over, so workers match their engine.
    pub fn fork(&self) -> Model {
        Model::from_shared_with_kernel(self.cfg.clone(), self.weights.clone(), self.kernel)
    }

    /// Swap the score kernel (and rebind the decode LUT to it).  Called
    /// by the engine BEFORE the decode pool forks its workers.
    pub fn set_kernel(&mut self, kernel: &'static dyn ScoreKernel) {
        self.kernel = kernel;
        self.lut.set_kernel(kernel);
    }

    /// Name of the active score kernel ("scalar" / "simd") — surfaced in
    /// the server startup log and the admin `metrics` reply.
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// Full-precision causal prefill; appends post-RoPE K/V to `cache` and
    /// returns the last position's logits.
    pub fn prefill(&mut self, tokens: &[u32], cache: &mut SequenceCache) -> Vec<f32> {
        let (logits, k_all, v_all) = self.prefill_kv(tokens);
        let t = tokens.len();
        cache.append_prefill(&k_all, &v_all, t);
        logits
    }

    /// Prefill that also returns the K/V block (L, Kv, T, d) — used by the
    /// SnapKV path, which filters rows before they enter the cache.
    pub fn prefill_kv(&mut self, tokens: &[u32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (logits, k, v, _) = self.prefill_kv_importance(tokens, 0);
        (logits, k, v)
    }

    /// Prefill that additionally accumulates SnapKV importance: the
    /// column-sums of post-softmax attention from the last
    /// `window` query positions, summed over layers and heads.
    ///
    /// NOTE: [`Model::prefill_chunk`] mirrors this layer stack and is
    /// held bit-identical to it by test — apply any math change (bias,
    /// norm eps, op order) to both.
    pub fn prefill_kv_importance(
        &mut self,
        tokens: &[u32],
        window: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let cfg = &self.cfg;
        let t = tokens.len();
        let (d, h, kv, dh) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
        let hq = cfg.q_per_kv();
        let scale = 1.0 / (dh as f32).sqrt();
        let embed = self.weights.get("embed");
        let mut x = vec![0.0f32; t * d];
        for (n, &tok) in tokens.iter().enumerate() {
            x[n * d..(n + 1) * d].copy_from_slice(embed.row(tok as usize));
        }

        let mut k_all = vec![0.0f32; cfg.n_layers * kv * t * dh];
        let mut v_all = vec![0.0f32; cfg.n_layers * kv * t * dh];
        let mut xn = vec![0.0f32; t * d];
        let mut q = vec![0.0f32; t * h * dh];
        let mut kl = vec![0.0f32; t * kv * dh];
        let mut vl = vec![0.0f32; t * kv * dh];
        let mut attn = vec![0.0f32; t * h * dh];
        let mut scores = vec![0.0f32; t];
        let mut importance = vec![0.0f32; t];

        for layer in 0..cfg.n_layers {
            let gamma = self.weights.layer("norm_attn", layer);
            for n in 0..t {
                rms_norm(&x[n * d..(n + 1) * d], gamma, 1e-5, &mut xn[n * d..(n + 1) * d]);
            }
            matmul_into(&xn, self.weights.layer("wq", layer), t, d, h * dh, &mut q);
            matmul_into(&xn, self.weights.layer("wk", layer), t, d, kv * dh, &mut kl);
            {
                let bk = self.weights.layer("bk", layer);
                for n in 0..t {
                    for j in 0..kv * dh {
                        kl[n * kv * dh + j] += bk[j];
                    }
                }
            }
            matmul_into(&xn, self.weights.layer("wv", layer), t, d, kv * dh, &mut vl);
            for n in 0..t {
                for head in 0..h {
                    rope_rotate_inplace(
                        &mut q[(n * h + head) * dh..(n * h + head + 1) * dh],
                        n as u32,
                        &self.freqs,
                    );
                }
                for head in 0..kv {
                    rope_rotate_inplace(
                        &mut kl[(n * kv + head) * dh..(n * kv + head + 1) * dh],
                        n as u32,
                        &self.freqs,
                    );
                }
            }
            // causal attention
            attn.fill(0.0);
            for n in 0..t {
                for head in 0..h {
                    let khead = head / hq;
                    let qrow = &q[(n * h + head) * dh..(n * h + head + 1) * dh];
                    for m in 0..=n {
                        scores[m] =
                            dot(qrow, &kl[(m * kv + khead) * dh..(m * kv + khead + 1) * dh])
                                * scale;
                    }
                    softmax_inplace(&mut scores[..=n]);
                    if window > 0 && n + window >= t {
                        for m in 0..=n {
                            importance[m] += scores[m];
                        }
                    }
                    let out = &mut attn[(n * h + head) * dh..(n * h + head + 1) * dh];
                    for m in 0..=n {
                        axpy(
                            scores[m],
                            &vl[(m * kv + khead) * dh..(m * kv + khead + 1) * dh],
                            out,
                        );
                    }
                }
            }
            // store K/V in (L, Kv, T, d) layout
            for n in 0..t {
                for head in 0..kv {
                    let dst = ((layer * kv + head) * t + n) * dh;
                    k_all[dst..dst + dh]
                        .copy_from_slice(&kl[(n * kv + head) * dh..(n * kv + head + 1) * dh]);
                    v_all[dst..dst + dh]
                        .copy_from_slice(&vl[(n * kv + head) * dh..(n * kv + head + 1) * dh]);
                }
            }
            // o proj + residual
            let wo = self.weights.layer("wo", layer);
            for n in 0..t {
                let mut o = vec![0.0f32; d];
                matmul_into(&attn[n * h * dh..(n + 1) * h * dh], wo, 1, h * dh, d, &mut o);
                for j in 0..d {
                    x[n * d + j] += o[j];
                }
            }
            // mlp
            let gm = self.weights.layer("norm_mlp", layer);
            let wg = self.weights.layer("w_gate", layer);
            let wu = self.weights.layer("w_up", layer);
            let wd = self.weights.layer("w_down", layer);
            let f = cfg.ffn;
            let mut gate = vec![0.0f32; f];
            let mut up = vec![0.0f32; f];
            let mut down = vec![0.0f32; d];
            let mut xrow = vec![0.0f32; d];
            for n in 0..t {
                rms_norm(&x[n * d..(n + 1) * d], gm, 1e-5, &mut xrow);
                matmul_into(&xrow, wg, 1, d, f, &mut gate);
                matmul_into(&xrow, wu, 1, d, f, &mut up);
                for j in 0..f {
                    gate[j] = silu(gate[j]) * up[j];
                }
                matmul_into(&gate, wd, 1, f, d, &mut down);
                for j in 0..d {
                    x[n * d + j] += down[j];
                }
            }
        }
        // final norm + logits at last position
        let gamma = self.weights.get("norm_final");
        let mut xl = vec![0.0f32; d];
        rms_norm(&x[(t - 1) * d..t * d], &gamma.data, 1e-5, &mut xl);
        let mut logits = vec![0.0f32; cfg.vocab];
        matmul_into(&xl, &self.weights.get("lm_head").data, 1, d, cfg.vocab, &mut logits);
        (logits, k_all, v_all, importance)
    }

    /// Resumable prefill: run `tokens` (one chunk of a prompt) through the
    /// stack, attending over everything already in `cache` — quantized key
    /// groups through the PolarQuant LUT, the fp residual tail densely —
    /// plus the chunk's own causal prefix, then append the chunk's
    /// post-RoPE K/V.  Returns the last chunk position's logits, so the
    /// final chunk of a prompt yields the first-token logits.
    ///
    /// `start_pos` must equal `cache.next_pos`; RoPE positions continue
    /// from it, so a prompt split into chunks of ANY size reproduces the
    /// unchunked [`Model::prefill`] positions exactly.
    ///
    /// `quantize_eagerly` picks where the chunk's K/V lands:
    ///
    /// * `false` (exact, the engine default): the chunk is appended with
    ///   group finalization DEFERRED, so every earlier prompt token is
    ///   still fp when later chunks score against it and the whole chunked
    ///   prefill is bit-identical to the unchunked one.  The caller must
    ///   [`SequenceCache::flush_groups`] after the last chunk; groups then
    ///   finalize in append order, exactly as the unchunked path's would.
    /// * `true` (memory-bound serving): full groups quantize as soon as a
    ///   chunk lands, so later chunks score the quantized region through
    ///   the LUT — cheaper residency during long prefills, at the paper's
    ///   quantization error instead of bit-exactness.
    ///
    /// `need_logits` should be true only for a prompt's FINAL chunk: the
    /// final norm + `d × vocab` lm_head projection is skipped (returning
    /// an empty vec) otherwise, since intermediate chunks' logits are
    /// never sampled and the wasted projection would inflate exactly the
    /// decode stall chunking exists to bound.
    ///
    /// This deliberately duplicates the layer stack of
    /// [`Model::prefill_kv_importance`] rather than delegating: the
    /// handwritten full-prompt pass is the independent reference that
    /// `chunked_prefill_is_bit_identical_to_unchunked` locks this kernel
    /// against bit-for-bit.  Any edit to either copy that diverges the
    /// math (bias, norm eps, op order) fails that test immediately —
    /// keep them in lock-step.
    pub fn prefill_chunk(
        &mut self,
        tokens: &[u32],
        start_pos: usize,
        cache: &mut SequenceCache,
        quantize_eagerly: bool,
        need_logits: bool,
    ) -> Vec<f32> {
        let cfg = self.cfg.clone();
        let c = tokens.len();
        assert!(c > 0, "empty prefill chunk");
        debug_assert_eq!(start_pos, cache.next_pos, "chunk must resume at cache.next_pos");
        let (d, h, kv, dh) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
        let hq = cfg.q_per_kv();
        let scale = 1.0 / (dh as f32).sqrt();
        let embed = self.weights.get("embed");
        let mut x = vec![0.0f32; c * d];
        for (n, &tok) in tokens.iter().enumerate() {
            x[n * d..(n + 1) * d].copy_from_slice(embed.row(tok as usize));
        }

        let mut k_all = vec![0.0f32; cfg.n_layers * kv * c * dh];
        let mut v_all = vec![0.0f32; cfg.n_layers * kv * c * dh];
        let mut xn = vec![0.0f32; c * d];
        let mut q = vec![0.0f32; c * h * dh];
        let mut kl = vec![0.0f32; c * kv * dh];
        let mut vl = vec![0.0f32; c * kv * dh];
        let mut attn = vec![0.0f32; c * h * dh];
        // LUT scratch sized for ALL the chunk's queries at once, so each
        // quantized group is unpacked and its basis built once per
        // (layer, kv-head) — not once per chunk row.  Only needed when
        // the cache already holds quantized groups (eager mode).
        let mut chunk_lut = (cache.quantized_len() > 0)
            .then(|| QkLut::with_kernel(cfg.polar_spec(), dh, c * hq, self.kernel));
        let mut scores: Vec<Vec<f32>> = vec![Vec::new(); c * hq];

        for layer in 0..cfg.n_layers {
            let gamma = self.weights.layer("norm_attn", layer);
            for n in 0..c {
                rms_norm(&x[n * d..(n + 1) * d], gamma, 1e-5, &mut xn[n * d..(n + 1) * d]);
            }
            matmul_into(&xn, self.weights.layer("wq", layer), c, d, h * dh, &mut q);
            matmul_into(&xn, self.weights.layer("wk", layer), c, d, kv * dh, &mut kl);
            {
                let bk = self.weights.layer("bk", layer);
                for n in 0..c {
                    for j in 0..kv * dh {
                        kl[n * kv * dh + j] += bk[j];
                    }
                }
            }
            matmul_into(&xn, self.weights.layer("wv", layer), c, d, kv * dh, &mut vl);
            for n in 0..c {
                let pos = (start_pos + n) as u32;
                for head in 0..h {
                    rope_rotate_inplace(
                        &mut q[(n * h + head) * dh..(n * h + head + 1) * dh],
                        pos,
                        &self.freqs,
                    );
                }
                for head in 0..kv {
                    rope_rotate_inplace(
                        &mut kl[(n * kv + head) * dh..(n * kv + head + 1) * dh],
                        pos,
                        &self.freqs,
                    );
                }
            }
            // mixed attention: cached (quantized via LUT + fp residual)
            // context, then the chunk's own causal prefix.  All cached
            // groups precede every chunk position, so the quantized
            // region needs no causal mask and all c×hq queries score it
            // in ONE scores_groups pass per kv-head — straight off the
            // (possibly shared) pages, no group copy.
            attn.fill(0.0);
            for khead in 0..kv {
                let st = cache.stream(layer, khead);
                let qlen = st.quantized_len();
                let rlen = st.resid_len();
                let resid_k = st.resid_k();
                let resid_v = st.resid_v();
                if let Some(lut) = chunk_lut.as_mut() {
                    let mut qs: Vec<&[f32]> = Vec::with_capacity(c * hq);
                    for n in 0..c {
                        for i in 0..hq {
                            let head = khead * hq + i;
                            qs.push(&q[(n * h + head) * dh..(n * h + head + 1) * dh]);
                        }
                    }
                    lut.scores_groups(&qs, st.key_groups(), &mut scores);
                } else {
                    for sc in scores.iter_mut() {
                        sc.clear();
                    }
                }
                for n in 0..c {
                    for i in 0..hq {
                        let head = khead * hq + i;
                        let qrow = &q[(n * h + head) * dh..(n * h + head + 1) * dh];
                        let sc = &mut scores[n * hq + i];
                        for r in 0..rlen {
                            sc.push(dot(qrow, &resid_k[r * dh..(r + 1) * dh]));
                        }
                        for m in 0..=n {
                            sc.push(dot(
                                qrow,
                                &kl[(m * kv + khead) * dh..(m * kv + khead + 1) * dh],
                            ));
                        }
                        debug_assert_eq!(sc.len(), qlen + rlen + n + 1);
                        for v in sc.iter_mut() {
                            *v *= scale;
                        }
                        softmax_inplace(sc);
                    }
                    for i in 0..hq {
                        let head = khead * hq + i;
                        let w = &scores[n * hq + i];
                        let out = &mut attn[(n * h + head) * dh..(n * h + head + 1) * dh];
                        let g = cfg.group;
                        for (gi, (kg, gv)) in st.groups().enumerate() {
                            let wslice = &w[gi * g..gi * g + kg.tokens];
                            match gv {
                                GroupValues::Fp(vals) => {
                                    for (m, &wm) in wslice.iter().enumerate() {
                                        axpy(wm, &vals[m * dh..(m + 1) * dh], out);
                                    }
                                }
                                GroupValues::Quant(enc) => {
                                    value::weighted_sum_into(wslice, enc, dh, out);
                                }
                            }
                        }
                        for r in 0..rlen {
                            axpy(w[qlen + r], &resid_v[r * dh..(r + 1) * dh], out);
                        }
                        for m in 0..=n {
                            axpy(
                                w[qlen + rlen + m],
                                &vl[(m * kv + khead) * dh..(m * kv + khead + 1) * dh],
                                out,
                            );
                        }
                    }
                }
            }
            // store this layer's chunk K/V in (L, Kv, C, d) layout
            for n in 0..c {
                for head in 0..kv {
                    let dst = ((layer * kv + head) * c + n) * dh;
                    k_all[dst..dst + dh]
                        .copy_from_slice(&kl[(n * kv + head) * dh..(n * kv + head + 1) * dh]);
                    v_all[dst..dst + dh]
                        .copy_from_slice(&vl[(n * kv + head) * dh..(n * kv + head + 1) * dh]);
                }
            }
            // o proj + residual (matmul_into zero-fills, so one buffer
            // serves every row)
            let wo = self.weights.layer("wo", layer);
            let mut o = vec![0.0f32; d];
            for n in 0..c {
                matmul_into(&attn[n * h * dh..(n + 1) * h * dh], wo, 1, h * dh, d, &mut o);
                for j in 0..d {
                    x[n * d + j] += o[j];
                }
            }
            // mlp
            let gm = self.weights.layer("norm_mlp", layer);
            let wg = self.weights.layer("w_gate", layer);
            let wu = self.weights.layer("w_up", layer);
            let wd = self.weights.layer("w_down", layer);
            let f = cfg.ffn;
            let mut gate = vec![0.0f32; f];
            let mut up = vec![0.0f32; f];
            let mut down = vec![0.0f32; d];
            let mut xrow = vec![0.0f32; d];
            for n in 0..c {
                rms_norm(&x[n * d..(n + 1) * d], gm, 1e-5, &mut xrow);
                matmul_into(&xrow, wg, 1, d, f, &mut gate);
                matmul_into(&xrow, wu, 1, d, f, &mut up);
                for j in 0..f {
                    gate[j] = silu(gate[j]) * up[j];
                }
                matmul_into(&gate, wd, 1, f, d, &mut down);
                for j in 0..d {
                    x[n * d + j] += down[j];
                }
            }
        }
        // final norm + logits at the chunk's last position (final chunk
        // only — intermediate chunks' logits are never sampled)
        let mut logits = Vec::new();
        if need_logits {
            let gamma = self.weights.get("norm_final");
            let mut xl = vec![0.0f32; d];
            rms_norm(&x[(c - 1) * d..c * d], &gamma.data, 1e-5, &mut xl);
            logits = vec![0.0f32; cfg.vocab];
            matmul_into(&xl, &self.weights.get("lm_head").data, 1, d, cfg.vocab, &mut logits);
        }

        if quantize_eagerly {
            cache.append_prefill(&k_all, &v_all, c);
        } else {
            cache.append_prefill_deferred(&k_all, &v_all, c);
        }
        logits
    }

    /// One decode step over the quantized cache: returns logits and
    /// appends this token's K/V.  The quantized-region scores go through
    /// the PolarQuant LUT — the paper's accelerated path.
    pub fn decode_step(&mut self, token: u32, cache: &mut SequenceCache) -> &[f32] {
        let cfg = self.cfg.clone();
        let (d, h, kv, dh) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
        let hq = cfg.q_per_kv();
        let scale = 1.0 / (dh as f32).sqrt();
        let pos = cache.next_pos as u32;

        self.x.copy_from_slice(self.weights.get("embed").row(token as usize));
        let mut new_k = vec![0.0f32; cfg.n_layers * kv * dh];
        let mut new_v = vec![0.0f32; cfg.n_layers * kv * dh];

        for layer in 0..cfg.n_layers {
            rms_norm(&self.x, self.weights.layer("norm_attn", layer), 1e-5, &mut self.xn);
            matmul_into(&self.xn, self.weights.layer("wq", layer), 1, d, h * dh, &mut self.q);
            matmul_into(&self.xn, self.weights.layer("wk", layer), 1, d, kv * dh, &mut self.k);
            {
                let bk = self.weights.layer("bk", layer);
                for j in 0..kv * dh {
                    self.k[j] += bk[j];
                }
            }
            matmul_into(&self.xn, self.weights.layer("wv", layer), 1, d, kv * dh, &mut self.v);
            for head in 0..h {
                rope_rotate_inplace(&mut self.q[head * dh..(head + 1) * dh], pos, &self.freqs);
            }
            for head in 0..kv {
                rope_rotate_inplace(&mut self.k[head * dh..(head + 1) * dh], pos, &self.freqs);
            }

            self.attn_out.fill(0.0);
            for khead in 0..kv {
                let st = cache.stream(layer, khead);
                let qlen = st.quantized_len();
                let rlen = st.resid_len();
                let resid_k = st.resid_k();
                let resid_v = st.resid_v();
                let total = qlen + rlen + 1;

                // 1) quantized region via LUT (all hq query heads at once),
                //    scoring straight off the (possibly shared) cache
                //    pages — no group copy on the hot path
                {
                    let qs: Vec<&[f32]> = (0..hq)
                        .map(|i| {
                            let head = khead * hq + i;
                            &self.q[head * dh..(head + 1) * dh]
                        })
                        .collect();
                    self.lut.scores_groups(&qs, st.key_groups(), &mut self.scores);
                }
                for (i, sc) in self.scores.iter_mut().enumerate() {
                    let head = khead * hq + i;
                    let qrow = &self.q[head * dh..(head + 1) * dh];
                    // 2) fp residual tail
                    for r in 0..rlen {
                        sc.push(dot(qrow, &resid_k[r * dh..(r + 1) * dh]));
                    }
                    // 3) self
                    sc.push(dot(qrow, &self.k[khead * dh..(khead + 1) * dh]));
                    debug_assert_eq!(sc.len(), total);
                    for v in sc.iter_mut() {
                        *v *= scale;
                    }
                    softmax_inplace(sc);
                }
                // value product
                for i in 0..hq {
                    let head = khead * hq + i;
                    let w = &self.scores[i];
                    let out = &mut self.attn_out[head * dh..(head + 1) * dh];
                    let g = cfg.group;
                    for (gi, (kg, gv)) in st.groups().enumerate() {
                        let wslice = &w[gi * g..gi * g + kg.tokens];
                        match gv {
                            GroupValues::Fp(vals) => {
                                for (n, &wn) in wslice.iter().enumerate() {
                                    axpy(wn, &vals[n * dh..(n + 1) * dh], out);
                                }
                            }
                            GroupValues::Quant(enc) => {
                                value::weighted_sum_into(wslice, enc, dh, out);
                            }
                        }
                    }
                    for r in 0..rlen {
                        axpy(w[qlen + r], &resid_v[r * dh..(r + 1) * dh], out);
                    }
                    axpy(w[total - 1], &self.v[khead * dh..(khead + 1) * dh], out);
                }
            }

            // o proj + residual
            matmul_into(
                &self.attn_out,
                self.weights.layer("wo", layer),
                1,
                h * dh,
                d,
                &mut self.o,
            );
            for j in 0..d {
                self.x[j] += self.o[j];
            }
            // mlp
            rms_norm(&self.x, self.weights.layer("norm_mlp", layer), 1e-5, &mut self.xn);
            matmul_into(&self.xn, self.weights.layer("w_gate", layer), 1, d, cfg.ffn, &mut self.ffn_gate);
            matmul_into(&self.xn, self.weights.layer("w_up", layer), 1, d, cfg.ffn, &mut self.ffn_up);
            for j in 0..cfg.ffn {
                self.ffn_gate[j] = silu(self.ffn_gate[j]) * self.ffn_up[j];
            }
            matmul_into(&self.ffn_gate, self.weights.layer("w_down", layer), 1, cfg.ffn, d, &mut self.o);
            for j in 0..d {
                self.x[j] += self.o[j];
            }

            // stash this layer's k/v
            new_k[layer * kv * dh..(layer + 1) * kv * dh].copy_from_slice(&self.k);
            new_v[layer * kv * dh..(layer + 1) * kv * dh].copy_from_slice(&self.v);
        }

        rms_norm(&self.x, &self.weights.get("norm_final").data, 1e-5, &mut self.xn[..d]);
        matmul_into(
            &self.xn[..d],
            &self.weights.get("lm_head").data,
            1,
            d,
            cfg.vocab,
            &mut self.logits,
        );
        cache.append_step(&new_k, &new_v);
        &self.logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::tiny();
        cfg.n_layers = 2;
        cfg.vocab = 64;
        cfg.d_model = 32;
        cfg.n_heads = 4;
        cfg.n_kv_heads = 2;
        cfg.head_dim = 16;
        cfg.ffn = 48;
        cfg.group = 8;
        cfg.resid = 16;
        cfg
    }

    #[test]
    fn decode_over_residual_matches_prefill() {
        // With bits high enough that nothing is quantized yet (prompt <
        // group), decode of token T must equal prefill logits over T+1.
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 5, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(17);
        let toks: Vec<u32> = (0..7).map(|_| rng.below(cfg.vocab) as u32).collect();
        let next: u32 = rng.below(cfg.vocab) as u32;

        let mut cache = SequenceCache::new(cfg.cache_config(None));
        let _ = model.prefill(&toks, &mut cache);
        assert_eq!(cache.quantized_len(), 0, "7 < group=8: all residual");
        let got = model.decode_step(next, &mut cache).to_vec();

        let mut full: Vec<u32> = toks.clone();
        full.push(next);
        let mut cache2 = SequenceCache::new(cfg.cache_config(None));
        let want = model.prefill(&full, &mut cache2);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 2e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn quantized_decode_stays_close_to_fp() {
        // Once groups quantize, logits drift but must stay close at 4/4
        // bits (the paper's near-lossless claim, natively).
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 6, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(18);
        let toks: Vec<u32> = (0..20).map(|_| rng.below(cfg.vocab) as u32).collect();
        let next = 3u32;

        let mut cache = SequenceCache::new(cfg.cache_config(None));
        model.prefill(&toks, &mut cache);
        assert_eq!(cache.quantized_len(), 16);
        let got = model.decode_step(next, &mut cache).to_vec();

        let mut full = toks.clone();
        full.push(next);
        let mut cache2 = SequenceCache::new(cfg.cache_config(None));
        let want = model.prefill(&full, &mut cache2);
        let cos = crate::tensor::ops::cosine(&got, &want);
        // toy geometry (dh=16, group=8) quantizes coarser than the paper's
        // d=128/g=128 setting; direction must still be preserved…
        assert!(cos > 0.95, "cos {cos}");
        // …and the fp argmax must stay in the quantized model's top-3
        // (strict argmax equality is seed-dependent at toy scale).
        let want_top = argmax(&want);
        let mut idx: Vec<usize> = (0..got.len()).collect();
        idx.sort_by(|&a, &b| got[b].partial_cmp(&got[a]).unwrap());
        assert!(idx[..3].contains(&want_top), "fp argmax {want_top} not in top-3 {:?}", &idx[..3]);
    }

    #[test]
    fn decode_steps_advance_cache() {
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 7, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut cache = SequenceCache::new(cfg.cache_config(None));
        model.prefill(&[1, 2, 3], &mut cache);
        for i in 0..10 {
            model.decode_step(i % cfg.vocab as u32, &mut cache);
        }
        assert_eq!(cache.len(), 13);
        assert_eq!(cache.next_pos, 13);
        assert_eq!(cache.quantized_len(), 8);
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_unchunked() {
        // Exact-mode chunked prefill (deferred finalization) must
        // reproduce Model::prefill bit-for-bit: same logits at the last
        // prompt position, same quantized groups, same residual — at ANY
        // chunk size, including chunk=1 and chunk > prompt.
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 21, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(31);
        let toks: Vec<u32> = (0..23).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut c_ref = SequenceCache::new(cfg.cache_config(None));
        let want = model.prefill(&toks, &mut c_ref);
        for chunk in [1usize, 3, 8, 23, 40] {
            let mut c = SequenceCache::new(cfg.cache_config(None));
            let mut got = Vec::new();
            let mut pos = 0;
            while pos < toks.len() {
                let take = chunk.min(toks.len() - pos);
                let last = pos + take == toks.len();
                let l = model.prefill_chunk(&toks[pos..pos + take], pos, &mut c, false, last);
                assert_eq!(l.is_empty(), !last, "logits only on the final chunk");
                got = l;
                pos += take;
            }
            c.flush_groups();
            assert_eq!(got, want, "chunk={chunk}: last-position logits differ");
            assert_eq!(c.next_pos, c_ref.next_pos);
            assert_eq!(c.quantized_len(), c_ref.quantized_len(), "chunk={chunk}");
            for l in 0..cfg.n_layers {
                for h in 0..cfg.n_kv_heads {
                    let a = c.stream(l, h);
                    let b = c_ref.stream(l, h);
                    assert_eq!(a.decode_keys(), b.decode_keys(), "chunk={chunk}: keys");
                    assert_eq!(a.resid_k(), b.resid_k(), "chunk={chunk}: resid_k");
                    assert_eq!(a.resid_v(), b.resid_v(), "chunk={chunk}: resid_v");
                }
            }
        }
    }

    #[test]
    fn single_token_chunk_matches_decode_step_over_quantized_cache() {
        // Eager mode against a cache holding quantized groups exercises
        // the LUT + residual + in-chunk mixed path; a 1-token chunk is
        // exactly one decode step, so the logits must agree bit-for-bit.
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 22, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(32);
        let toks: Vec<u32> = (0..20).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut cache = SequenceCache::new(cfg.cache_config(Some(4)));
        model.prefill(&toks, &mut cache);
        assert!(cache.quantized_len() > 0, "need quantized groups for the LUT path");
        let mut c2 = cache.clone();
        let want = model.decode_step(9, &mut cache).to_vec();
        let got = model.prefill_chunk(&[9], 20, &mut c2, true, true);
        assert_eq!(got, want);
        assert_eq!(c2.len(), cache.len());
        assert_eq!(c2.quantized_len(), cache.quantized_len());
    }

    #[test]
    fn eager_chunked_prefill_stays_close_to_exact() {
        // Eager finalization scores later chunks against quantized keys —
        // not bit-identical, but within the paper's near-lossless drift.
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 23, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(33);
        let toks: Vec<u32> = (0..24).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut c_ref = SequenceCache::new(cfg.cache_config(None));
        let want = model.prefill(&toks, &mut c_ref);
        let mut c = SequenceCache::new(cfg.cache_config(None));
        let mut got = Vec::new();
        let n_chunks = toks.chunks(8).count();
        for (ci, ch) in toks.chunks(8).enumerate() {
            got = model.prefill_chunk(ch, ci * 8, &mut c, true, ci + 1 == n_chunks);
        }
        assert_eq!(c.quantized_len(), 24, "eager chunks finalized groups mid-prefill");
        let cos = crate::tensor::ops::cosine(&got, &want);
        assert!(cos > 0.95, "cos {cos}");
    }

    #[test]
    fn quantized_values_barely_move_logits() {
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 8, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(19);
        let toks: Vec<u32> = (0..24).map(|_| rng.below(cfg.vocab) as u32).collect();

        let mut c_fp = SequenceCache::new(cfg.cache_config(None));
        model.prefill(&toks, &mut c_fp);
        let a = model.decode_step(1, &mut c_fp).to_vec();

        let mut c_q = SequenceCache::new(cfg.cache_config(Some(4)));
        model.prefill(&toks, &mut c_q);
        let b = model.decode_step(1, &mut c_q).to_vec();
        let cos = crate::tensor::ops::cosine(&a, &b);
        assert!(cos > 0.99, "cos {cos}");
    }
}
