//! Native forward passes.
//!
//! `prefill` runs full-precision causal attention over the prompt (the
//! JAX prefill graph's twin) and streams the post-RoPE K/V into the
//! quantized cache.  `prefill_chunk` is its resumable form: one chunk of
//! a prompt at a time, attending over whatever the cache already holds
//! (quantized groups via the LUT, fp residual densely) plus the chunk's
//! own causal prefix — the primitive under the engine's chunked-prefill
//! continuous batching.  `decode_step` is the serving hot path: attention
//! scores over the quantized region come from the PolarQuant LUT
//! ([`crate::quant::lut::QkLut`]), the fp residual tail and the current
//! token are scored densely, and the value product uses the fused
//! weighted-sum kernel when values are quantized.

use std::sync::Arc;

use crate::kvcache::stream::GroupValues;
use crate::kvcache::SequenceCache;
use crate::quant::lut::{default_kernel, QkLut, ScoreKernel};
use crate::quant::value;
use crate::quant::DraftSpec;
use crate::tensor::ops::*;
use crate::trace::{TraceKind, TraceRecorder};

use super::config::ModelConfig;
use super::sampling::logprob_at;
use super::weights::Weights;

/// Which logits rows [`Model::chunk_forward`] materializes.
#[derive(Clone, Copy, PartialEq)]
enum ChunkLogits {
    /// none (intermediate prefill chunks — never sampled)
    None,
    /// final position only (a prompt's last chunk)
    Last,
    /// every position (speculative verification)
    All,
}

/// Outcome of one speculative decode round
/// ([`Model::speculative_decode`]).
pub struct SpecDecode {
    /// tokens emitted this round, in order, with their full-softmax
    /// logprobs (0.0 unless `want_logprob` was set)
    pub tokens: Vec<(u32, f32)>,
    /// draft tokens proposed (the window may be capped below k by the
    /// group boundary or the generation budget)
    pub drafted: u32,
    /// drafts accepted by exact verification (pre-clamp: a draft that
    /// verification confirmed but the stop/budget clamp then cut still
    /// counts as accepted for the run-length metrics)
    pub accepted: u32,
}

pub struct Model {
    pub cfg: ModelConfig,
    /// shared, read-only: [`Model::fork`] hands the same weights to every
    /// decode-pool worker; only the scratch below is per-thread
    pub weights: Arc<Weights>,
    freqs: Vec<f32>,
    /// the score-kernel backend every LUT built by this model uses
    /// ([`crate::quant::lut::select_kernel`]); [`Model::fork`] propagates
    /// it, so decode-pool workers inherit the engine's `--kernel` choice
    kernel: &'static dyn ScoreKernel,
    // decode-step scratch (allocation-free steady state)
    lut: QkLut,
    /// coarse self-drafting scorer over the SAME cached codes
    /// ([`Model::set_draft`]); `None` until speculation is enabled
    draft_lut: Option<QkLut>,
    draft_spec: Option<DraftSpec>,
    /// observation-only trace hook ([`Model::set_trace`]; propagated by
    /// [`Model::fork`] so decode-pool workers record into the engine's
    /// ring); `trace_req` names the request whose decode runs next
    trace: Option<Arc<TraceRecorder>>,
    trace_req: u64,
    scores: Vec<Vec<f32>>,
    attn_out: Vec<f32>,
    x: Vec<f32>,
    xn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    o: Vec<f32>,
    ffn_gate: Vec<f32>,
    ffn_up: Vec<f32>,
    logits: Vec<f32>,
}

impl Model {
    pub fn new(cfg: ModelConfig, weights: Weights) -> Self {
        Model::from_shared(cfg, Arc::new(weights))
    }

    /// Build a model over already-shared weights (decode-pool workers).
    pub fn from_shared(cfg: ModelConfig, weights: Arc<Weights>) -> Self {
        Model::from_shared_with_kernel(cfg, weights, default_kernel())
    }

    /// [`Model::from_shared`] with an explicit [`ScoreKernel`] — the
    /// engine resolves `--kernel` once and builds/forks models through
    /// this so every LUT in the process agrees.
    pub fn from_shared_with_kernel(
        cfg: ModelConfig,
        weights: Arc<Weights>,
        kernel: &'static dyn ScoreKernel,
    ) -> Self {
        let dh = cfg.head_dim;
        let hq = cfg.q_per_kv();
        Model {
            freqs: rope_freqs(dh, cfg.rope_base),
            kernel,
            lut: QkLut::with_kernel(cfg.polar_spec(), dh, hq, kernel),
            draft_lut: None,
            draft_spec: None,
            trace: None,
            trace_req: 0,
            scores: vec![Vec::new(); hq],
            attn_out: vec![0.0; cfg.n_heads * dh],
            x: vec![0.0; cfg.d_model],
            xn: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.n_heads * dh],
            k: vec![0.0; cfg.n_kv_heads * dh],
            v: vec![0.0; cfg.n_kv_heads * dh],
            o: vec![0.0; cfg.d_model],
            ffn_gate: vec![0.0; cfg.ffn],
            ffn_up: vec![0.0; cfg.ffn],
            logits: vec![0.0; cfg.vocab],
            cfg,
            weights,
        }
    }

    /// A new model sharing these weights with FRESH scratch (LUT, score
    /// and activation buffers) — what each decode-pool worker thread owns.
    /// Cost: a handful of small allocations; the weights are never copied.
    /// The score kernel carries over, so workers match their engine.
    pub fn fork(&self) -> Model {
        let mut m =
            Model::from_shared_with_kernel(self.cfg.clone(), self.weights.clone(), self.kernel);
        if let Some(draft) = self.draft_spec {
            m.set_draft(draft).expect("draft spec was validated when first set");
        }
        m.trace = self.trace.clone();
        m
    }

    /// Swap the score kernel (and rebind the decode LUTs to it).  Called
    /// by the engine BEFORE the decode pool forks its workers.
    pub fn set_kernel(&mut self, kernel: &'static dyn ScoreKernel) {
        self.kernel = kernel;
        self.lut.set_kernel(kernel);
        if let Some(dl) = self.draft_lut.as_mut() {
            dl.set_kernel(kernel);
        }
    }

    /// Enable self-drafting: build the coarse draft scorer (a [`QkLut`]
    /// that truncates the stored codes to `draft`'s bit widths while
    /// staging — zero extra quantization passes, zero extra cache bytes).
    /// Propagated by [`Model::fork`], so decode-pool workers inherit it.
    pub fn set_draft(&mut self, draft: DraftSpec) -> Result<(), String> {
        let dh = self.cfg.head_dim;
        let hq = self.cfg.q_per_kv();
        self.draft_lut =
            Some(QkLut::with_draft(self.cfg.polar_spec(), draft, dh, hq, self.kernel)?);
        self.draft_spec = Some(draft);
        Ok(())
    }

    /// The active draft plane, if speculation is enabled.
    pub fn draft_spec(&self) -> Option<DraftSpec> {
        self.draft_spec
    }

    /// Install the engine's trace recorder.  Propagated by
    /// [`Model::fork`], so decode-pool workers record into the same
    /// ring.  Observation-only: tracing never changes model output.
    pub fn set_trace(&mut self, rec: Arc<TraceRecorder>) {
        self.trace = Some(rec);
    }

    /// The recorder installed by [`Model::set_trace`], if any.
    pub fn trace(&self) -> Option<&Arc<TraceRecorder>> {
        self.trace.as_ref()
    }

    /// Name the request whose decode runs next on this model — the key
    /// for the `speculative_round` events recorded at the source in
    /// [`Model::speculative_decode`].
    pub fn set_trace_request(&mut self, id: u64) {
        self.trace_req = id;
    }

    /// Name of the active score kernel ("scalar" / "simd") — surfaced in
    /// the server startup log and the admin `metrics` reply.
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// Full-precision causal prefill; appends post-RoPE K/V to `cache` and
    /// returns the last position's logits.
    pub fn prefill(&mut self, tokens: &[u32], cache: &mut SequenceCache) -> Vec<f32> {
        let (logits, k_all, v_all) = self.prefill_kv(tokens);
        let t = tokens.len();
        cache.append_prefill(&k_all, &v_all, t);
        logits
    }

    /// Prefill that also returns the K/V block (L, Kv, T, d) — used by the
    /// SnapKV path, which filters rows before they enter the cache.
    pub fn prefill_kv(&mut self, tokens: &[u32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (logits, k, v, _) = self.prefill_kv_importance(tokens, 0);
        (logits, k, v)
    }

    /// Prefill that additionally accumulates SnapKV importance: the
    /// column-sums of post-softmax attention from the last
    /// `window` query positions, summed over layers and heads.
    ///
    /// NOTE: [`Model::prefill_chunk`] mirrors this layer stack and is
    /// held bit-identical to it by test — apply any math change (bias,
    /// norm eps, op order) to both.
    pub fn prefill_kv_importance(
        &mut self,
        tokens: &[u32],
        window: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let cfg = &self.cfg;
        let t = tokens.len();
        let (d, h, kv, dh) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
        let hq = cfg.q_per_kv();
        let scale = 1.0 / (dh as f32).sqrt();
        let embed = self.weights.get("embed");
        let mut x = vec![0.0f32; t * d];
        for (n, &tok) in tokens.iter().enumerate() {
            x[n * d..(n + 1) * d].copy_from_slice(embed.row(tok as usize));
        }

        let mut k_all = vec![0.0f32; cfg.n_layers * kv * t * dh];
        let mut v_all = vec![0.0f32; cfg.n_layers * kv * t * dh];
        let mut xn = vec![0.0f32; t * d];
        let mut q = vec![0.0f32; t * h * dh];
        let mut kl = vec![0.0f32; t * kv * dh];
        let mut vl = vec![0.0f32; t * kv * dh];
        let mut attn = vec![0.0f32; t * h * dh];
        let mut scores = vec![0.0f32; t];
        let mut importance = vec![0.0f32; t];

        for layer in 0..cfg.n_layers {
            let gamma = self.weights.layer("norm_attn", layer);
            for n in 0..t {
                rms_norm(&x[n * d..(n + 1) * d], gamma, 1e-5, &mut xn[n * d..(n + 1) * d]);
            }
            matmul_into(&xn, self.weights.layer("wq", layer), t, d, h * dh, &mut q);
            matmul_into(&xn, self.weights.layer("wk", layer), t, d, kv * dh, &mut kl);
            {
                let bk = self.weights.layer("bk", layer);
                for n in 0..t {
                    for j in 0..kv * dh {
                        kl[n * kv * dh + j] += bk[j];
                    }
                }
            }
            matmul_into(&xn, self.weights.layer("wv", layer), t, d, kv * dh, &mut vl);
            for n in 0..t {
                for head in 0..h {
                    rope_rotate_inplace(
                        &mut q[(n * h + head) * dh..(n * h + head + 1) * dh],
                        n as u32,
                        &self.freqs,
                    );
                }
                for head in 0..kv {
                    rope_rotate_inplace(
                        &mut kl[(n * kv + head) * dh..(n * kv + head + 1) * dh],
                        n as u32,
                        &self.freqs,
                    );
                }
            }
            // causal attention
            attn.fill(0.0);
            for n in 0..t {
                for head in 0..h {
                    let khead = head / hq;
                    let qrow = &q[(n * h + head) * dh..(n * h + head + 1) * dh];
                    for m in 0..=n {
                        scores[m] =
                            dot(qrow, &kl[(m * kv + khead) * dh..(m * kv + khead + 1) * dh])
                                * scale;
                    }
                    softmax_inplace(&mut scores[..=n]);
                    if window > 0 && n + window >= t {
                        for m in 0..=n {
                            importance[m] += scores[m];
                        }
                    }
                    let out = &mut attn[(n * h + head) * dh..(n * h + head + 1) * dh];
                    for m in 0..=n {
                        axpy(
                            scores[m],
                            &vl[(m * kv + khead) * dh..(m * kv + khead + 1) * dh],
                            out,
                        );
                    }
                }
            }
            // store K/V in (L, Kv, T, d) layout
            for n in 0..t {
                for head in 0..kv {
                    let dst = ((layer * kv + head) * t + n) * dh;
                    k_all[dst..dst + dh]
                        .copy_from_slice(&kl[(n * kv + head) * dh..(n * kv + head + 1) * dh]);
                    v_all[dst..dst + dh]
                        .copy_from_slice(&vl[(n * kv + head) * dh..(n * kv + head + 1) * dh]);
                }
            }
            // o proj + residual
            let wo = self.weights.layer("wo", layer);
            for n in 0..t {
                let mut o = vec![0.0f32; d];
                matmul_into(&attn[n * h * dh..(n + 1) * h * dh], wo, 1, h * dh, d, &mut o);
                for j in 0..d {
                    x[n * d + j] += o[j];
                }
            }
            // mlp
            let gm = self.weights.layer("norm_mlp", layer);
            let wg = self.weights.layer("w_gate", layer);
            let wu = self.weights.layer("w_up", layer);
            let wd = self.weights.layer("w_down", layer);
            let f = cfg.ffn;
            let mut gate = vec![0.0f32; f];
            let mut up = vec![0.0f32; f];
            let mut down = vec![0.0f32; d];
            let mut xrow = vec![0.0f32; d];
            for n in 0..t {
                rms_norm(&x[n * d..(n + 1) * d], gm, 1e-5, &mut xrow);
                matmul_into(&xrow, wg, 1, d, f, &mut gate);
                matmul_into(&xrow, wu, 1, d, f, &mut up);
                for j in 0..f {
                    gate[j] = silu(gate[j]) * up[j];
                }
                matmul_into(&gate, wd, 1, f, d, &mut down);
                for j in 0..d {
                    x[n * d + j] += down[j];
                }
            }
        }
        // final norm + logits at last position
        let gamma = self.weights.get("norm_final");
        let mut xl = vec![0.0f32; d];
        rms_norm(&x[(t - 1) * d..t * d], &gamma.data, 1e-5, &mut xl);
        let mut logits = vec![0.0f32; cfg.vocab];
        matmul_into(&xl, &self.weights.get("lm_head").data, 1, d, cfg.vocab, &mut logits);
        (logits, k_all, v_all, importance)
    }

    /// Resumable prefill: run `tokens` (one chunk of a prompt) through the
    /// stack, attending over everything already in `cache` — quantized key
    /// groups through the PolarQuant LUT, the fp residual tail densely —
    /// plus the chunk's own causal prefix, then append the chunk's
    /// post-RoPE K/V.  Returns the last chunk position's logits, so the
    /// final chunk of a prompt yields the first-token logits.
    ///
    /// `start_pos` must equal `cache.next_pos`; RoPE positions continue
    /// from it, so a prompt split into chunks of ANY size reproduces the
    /// unchunked [`Model::prefill`] positions exactly.
    ///
    /// `quantize_eagerly` picks where the chunk's K/V lands:
    ///
    /// * `false` (exact, the engine default): the chunk is appended with
    ///   group finalization DEFERRED, so every earlier prompt token is
    ///   still fp when later chunks score against it and the whole chunked
    ///   prefill is bit-identical to the unchunked one.  The caller must
    ///   [`SequenceCache::flush_groups`] after the last chunk; groups then
    ///   finalize in append order, exactly as the unchunked path's would.
    /// * `true` (memory-bound serving): full groups quantize as soon as a
    ///   chunk lands, so later chunks score the quantized region through
    ///   the LUT — cheaper residency during long prefills, at the paper's
    ///   quantization error instead of bit-exactness.
    ///
    /// `need_logits` should be true only for a prompt's FINAL chunk: the
    /// final norm + `d × vocab` lm_head projection is skipped (returning
    /// an empty vec) otherwise, since intermediate chunks' logits are
    /// never sampled and the wasted projection would inflate exactly the
    /// decode stall chunking exists to bound.
    ///
    /// The chunk stack itself lives in [`Model::chunk_forward`] (shared
    /// with speculative verification); this wrapper appends the chunk's
    /// K/V and unwraps the final-position logits.
    pub fn prefill_chunk(
        &mut self,
        tokens: &[u32],
        start_pos: usize,
        cache: &mut SequenceCache,
        quantize_eagerly: bool,
        need_logits: bool,
    ) -> Vec<f32> {
        let mode = if need_logits { ChunkLogits::Last } else { ChunkLogits::None };
        let (mut logits, k_all, v_all) = self.chunk_forward(tokens, start_pos, cache, mode);
        if quantize_eagerly {
            cache.append_prefill(&k_all, &v_all, tokens.len());
        } else {
            cache.append_prefill_deferred(&k_all, &v_all, tokens.len());
        }
        logits.pop().unwrap_or_default()
    }

    /// Exact batched VERIFICATION forward for speculative decoding: run
    /// the proposed window through the chunk stack, attending over the
    /// cache plus the window's own causal prefix, and return EVERY
    /// position's logits along with the window's post-RoPE K/V block
    /// (`(L, Kv, C, d)`) — WITHOUT appending anything.  The caller
    /// appends only the accepted prefix's rows
    /// ([`Model::speculative_decode`]), so rejected drafts never touch
    /// the cache.  Provided the window fits inside the current group's
    /// residual headroom (no page cut can land mid-window), every
    /// position's logits are bit-identical to sequential
    /// [`Model::decode_step`] calls: the chunk stack scores the same
    /// quantized-groups + fp-residual + in-window-prefix sets with the
    /// same op order, and all tensor ops are row-independent.
    pub fn verify_chunk(
        &mut self,
        tokens: &[u32],
        cache: &SequenceCache,
    ) -> (Vec<Vec<f32>>, Vec<f32>, Vec<f32>) {
        self.chunk_forward(tokens, cache.next_pos, cache, ChunkLogits::All)
    }

    /// The shared chunk stack under [`Model::prefill_chunk`] and
    /// [`Model::verify_chunk`]: forward `tokens` against the (read-only)
    /// cache, returning the requested logits rows and the chunk's K/V in
    /// `(L, Kv, C, d)` layout.  Appending is the caller's business.
    ///
    /// This deliberately duplicates the layer stack of
    /// [`Model::prefill_kv_importance`] rather than delegating: the
    /// handwritten full-prompt pass is the independent reference that
    /// `chunked_prefill_is_bit_identical_to_unchunked` locks this kernel
    /// against bit-for-bit.  Any edit to either copy that diverges the
    /// math (bias, norm eps, op order) fails that test immediately —
    /// keep them in lock-step.
    fn chunk_forward(
        &mut self,
        tokens: &[u32],
        start_pos: usize,
        cache: &SequenceCache,
        mode: ChunkLogits,
    ) -> (Vec<Vec<f32>>, Vec<f32>, Vec<f32>) {
        let cfg = self.cfg.clone();
        let c = tokens.len();
        assert!(c > 0, "empty chunk");
        debug_assert_eq!(start_pos, cache.next_pos, "chunk must resume at cache.next_pos");
        let (d, h, kv, dh) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
        let hq = cfg.q_per_kv();
        let scale = 1.0 / (dh as f32).sqrt();
        let embed = self.weights.get("embed");
        let mut x = vec![0.0f32; c * d];
        for (n, &tok) in tokens.iter().enumerate() {
            x[n * d..(n + 1) * d].copy_from_slice(embed.row(tok as usize));
        }

        let mut k_all = vec![0.0f32; cfg.n_layers * kv * c * dh];
        let mut v_all = vec![0.0f32; cfg.n_layers * kv * c * dh];
        let mut xn = vec![0.0f32; c * d];
        let mut q = vec![0.0f32; c * h * dh];
        let mut kl = vec![0.0f32; c * kv * dh];
        let mut vl = vec![0.0f32; c * kv * dh];
        let mut attn = vec![0.0f32; c * h * dh];
        // LUT scratch sized for ALL the chunk's queries at once, so each
        // quantized group is unpacked and its basis built once per
        // (layer, kv-head) — not once per chunk row.  Only needed when
        // the cache already holds quantized groups (eager mode).
        let mut chunk_lut = (cache.quantized_len() > 0)
            .then(|| QkLut::with_kernel(cfg.polar_spec(), dh, c * hq, self.kernel));
        let mut scores: Vec<Vec<f32>> = vec![Vec::new(); c * hq];

        for layer in 0..cfg.n_layers {
            let gamma = self.weights.layer("norm_attn", layer);
            for n in 0..c {
                rms_norm(&x[n * d..(n + 1) * d], gamma, 1e-5, &mut xn[n * d..(n + 1) * d]);
            }
            matmul_into(&xn, self.weights.layer("wq", layer), c, d, h * dh, &mut q);
            matmul_into(&xn, self.weights.layer("wk", layer), c, d, kv * dh, &mut kl);
            {
                let bk = self.weights.layer("bk", layer);
                for n in 0..c {
                    for j in 0..kv * dh {
                        kl[n * kv * dh + j] += bk[j];
                    }
                }
            }
            matmul_into(&xn, self.weights.layer("wv", layer), c, d, kv * dh, &mut vl);
            for n in 0..c {
                let pos = (start_pos + n) as u32;
                for head in 0..h {
                    rope_rotate_inplace(
                        &mut q[(n * h + head) * dh..(n * h + head + 1) * dh],
                        pos,
                        &self.freqs,
                    );
                }
                for head in 0..kv {
                    rope_rotate_inplace(
                        &mut kl[(n * kv + head) * dh..(n * kv + head + 1) * dh],
                        pos,
                        &self.freqs,
                    );
                }
            }
            // mixed attention: cached (quantized via LUT + fp residual)
            // context, then the chunk's own causal prefix.  All cached
            // groups precede every chunk position, so the quantized
            // region needs no causal mask and all c×hq queries score it
            // in ONE batched walk per kv-head ([`QkLut::verify_batch`])
            // — straight off the (possibly shared) pages, no group copy.
            attn.fill(0.0);
            for khead in 0..kv {
                let st = cache.stream(layer, khead);
                let qlen = st.quantized_len();
                let rlen = st.resid_len();
                let resid_k = st.resid_k();
                let resid_v = st.resid_v();
                if let Some(lut) = chunk_lut.as_mut() {
                    let mut qs: Vec<&[f32]> = Vec::with_capacity(c * hq);
                    for n in 0..c {
                        for i in 0..hq {
                            let head = khead * hq + i;
                            qs.push(&q[(n * h + head) * dh..(n * h + head + 1) * dh]);
                        }
                    }
                    lut.verify_batch(&qs, st.key_groups(), &mut scores);
                } else {
                    for sc in scores.iter_mut() {
                        sc.clear();
                    }
                }
                for n in 0..c {
                    for i in 0..hq {
                        let head = khead * hq + i;
                        let qrow = &q[(n * h + head) * dh..(n * h + head + 1) * dh];
                        let sc = &mut scores[n * hq + i];
                        for r in 0..rlen {
                            sc.push(dot(qrow, &resid_k[r * dh..(r + 1) * dh]));
                        }
                        for m in 0..=n {
                            sc.push(dot(
                                qrow,
                                &kl[(m * kv + khead) * dh..(m * kv + khead + 1) * dh],
                            ));
                        }
                        debug_assert_eq!(sc.len(), qlen + rlen + n + 1);
                        for v in sc.iter_mut() {
                            *v *= scale;
                        }
                        softmax_inplace(sc);
                    }
                    for i in 0..hq {
                        let head = khead * hq + i;
                        let w = &scores[n * hq + i];
                        let out = &mut attn[(n * h + head) * dh..(n * h + head + 1) * dh];
                        let g = cfg.group;
                        for (gi, (kg, gv)) in st.groups().enumerate() {
                            let wslice = &w[gi * g..gi * g + kg.tokens];
                            match gv {
                                GroupValues::Fp(vals) => {
                                    for (m, &wm) in wslice.iter().enumerate() {
                                        axpy(wm, &vals[m * dh..(m + 1) * dh], out);
                                    }
                                }
                                GroupValues::Quant(enc) => {
                                    value::weighted_sum_into(wslice, enc, dh, out);
                                }
                            }
                        }
                        for r in 0..rlen {
                            axpy(w[qlen + r], &resid_v[r * dh..(r + 1) * dh], out);
                        }
                        for m in 0..=n {
                            axpy(
                                w[qlen + rlen + m],
                                &vl[(m * kv + khead) * dh..(m * kv + khead + 1) * dh],
                                out,
                            );
                        }
                    }
                }
            }
            // store this layer's chunk K/V in (L, Kv, C, d) layout
            for n in 0..c {
                for head in 0..kv {
                    let dst = ((layer * kv + head) * c + n) * dh;
                    k_all[dst..dst + dh]
                        .copy_from_slice(&kl[(n * kv + head) * dh..(n * kv + head + 1) * dh]);
                    v_all[dst..dst + dh]
                        .copy_from_slice(&vl[(n * kv + head) * dh..(n * kv + head + 1) * dh]);
                }
            }
            // o proj + residual (matmul_into zero-fills, so one buffer
            // serves every row)
            let wo = self.weights.layer("wo", layer);
            let mut o = vec![0.0f32; d];
            for n in 0..c {
                matmul_into(&attn[n * h * dh..(n + 1) * h * dh], wo, 1, h * dh, d, &mut o);
                for j in 0..d {
                    x[n * d + j] += o[j];
                }
            }
            // mlp
            let gm = self.weights.layer("norm_mlp", layer);
            let wg = self.weights.layer("w_gate", layer);
            let wu = self.weights.layer("w_up", layer);
            let wd = self.weights.layer("w_down", layer);
            let f = cfg.ffn;
            let mut gate = vec![0.0f32; f];
            let mut up = vec![0.0f32; f];
            let mut down = vec![0.0f32; d];
            let mut xrow = vec![0.0f32; d];
            for n in 0..c {
                rms_norm(&x[n * d..(n + 1) * d], gm, 1e-5, &mut xrow);
                matmul_into(&xrow, wg, 1, d, f, &mut gate);
                matmul_into(&xrow, wu, 1, d, f, &mut up);
                for j in 0..f {
                    gate[j] = silu(gate[j]) * up[j];
                }
                matmul_into(&gate, wd, 1, f, d, &mut down);
                for j in 0..d {
                    x[n * d + j] += down[j];
                }
            }
        }
        // final norm + lm_head for the requested rows (prefill chunks
        // need at most the last position; verification samples them all)
        let first = match mode {
            ChunkLogits::None => c,
            ChunkLogits::Last => c - 1,
            ChunkLogits::All => 0,
        };
        let mut logits_all = Vec::with_capacity(c - first);
        if first < c {
            let gamma = self.weights.get("norm_final");
            let lm_head = self.weights.get("lm_head");
            let mut xl = vec![0.0f32; d];
            for n in first..c {
                rms_norm(&x[n * d..(n + 1) * d], &gamma.data, 1e-5, &mut xl);
                let mut logits = vec![0.0f32; cfg.vocab];
                matmul_into(&xl, &lm_head.data, 1, d, cfg.vocab, &mut logits);
                logits_all.push(logits);
            }
        }
        (logits_all, k_all, v_all)
    }

    /// One decode step over the quantized cache: returns logits and
    /// appends this token's K/V.  The quantized-region scores go through
    /// the PolarQuant LUT — the paper's accelerated path.
    pub fn decode_step(&mut self, token: u32, cache: &mut SequenceCache) -> &[f32] {
        let cfg = self.cfg.clone();
        let (d, h, kv, dh) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
        let hq = cfg.q_per_kv();
        let scale = 1.0 / (dh as f32).sqrt();
        let pos = cache.next_pos as u32;

        self.x.copy_from_slice(self.weights.get("embed").row(token as usize));
        let mut new_k = vec![0.0f32; cfg.n_layers * kv * dh];
        let mut new_v = vec![0.0f32; cfg.n_layers * kv * dh];

        for layer in 0..cfg.n_layers {
            rms_norm(&self.x, self.weights.layer("norm_attn", layer), 1e-5, &mut self.xn);
            matmul_into(&self.xn, self.weights.layer("wq", layer), 1, d, h * dh, &mut self.q);
            matmul_into(&self.xn, self.weights.layer("wk", layer), 1, d, kv * dh, &mut self.k);
            {
                let bk = self.weights.layer("bk", layer);
                for j in 0..kv * dh {
                    self.k[j] += bk[j];
                }
            }
            matmul_into(&self.xn, self.weights.layer("wv", layer), 1, d, kv * dh, &mut self.v);
            for head in 0..h {
                rope_rotate_inplace(&mut self.q[head * dh..(head + 1) * dh], pos, &self.freqs);
            }
            for head in 0..kv {
                rope_rotate_inplace(&mut self.k[head * dh..(head + 1) * dh], pos, &self.freqs);
            }

            self.attn_out.fill(0.0);
            for khead in 0..kv {
                let st = cache.stream(layer, khead);
                let qlen = st.quantized_len();
                let rlen = st.resid_len();
                let resid_k = st.resid_k();
                let resid_v = st.resid_v();
                let total = qlen + rlen + 1;

                // 1) quantized region via LUT (all hq query heads at once),
                //    scoring straight off the (possibly shared) cache
                //    pages — no group copy on the hot path
                {
                    let qs: Vec<&[f32]> = (0..hq)
                        .map(|i| {
                            let head = khead * hq + i;
                            &self.q[head * dh..(head + 1) * dh]
                        })
                        .collect();
                    self.lut.scores_groups(&qs, st.key_groups(), &mut self.scores);
                }
                for (i, sc) in self.scores.iter_mut().enumerate() {
                    let head = khead * hq + i;
                    let qrow = &self.q[head * dh..(head + 1) * dh];
                    // 2) fp residual tail
                    for r in 0..rlen {
                        sc.push(dot(qrow, &resid_k[r * dh..(r + 1) * dh]));
                    }
                    // 3) self
                    sc.push(dot(qrow, &self.k[khead * dh..(khead + 1) * dh]));
                    debug_assert_eq!(sc.len(), total);
                    for v in sc.iter_mut() {
                        *v *= scale;
                    }
                    softmax_inplace(sc);
                }
                // value product
                for i in 0..hq {
                    let head = khead * hq + i;
                    let w = &self.scores[i];
                    let out = &mut self.attn_out[head * dh..(head + 1) * dh];
                    let g = cfg.group;
                    for (gi, (kg, gv)) in st.groups().enumerate() {
                        let wslice = &w[gi * g..gi * g + kg.tokens];
                        match gv {
                            GroupValues::Fp(vals) => {
                                for (n, &wn) in wslice.iter().enumerate() {
                                    axpy(wn, &vals[n * dh..(n + 1) * dh], out);
                                }
                            }
                            GroupValues::Quant(enc) => {
                                value::weighted_sum_into(wslice, enc, dh, out);
                            }
                        }
                    }
                    for r in 0..rlen {
                        axpy(w[qlen + r], &resid_v[r * dh..(r + 1) * dh], out);
                    }
                    axpy(w[total - 1], &self.v[khead * dh..(khead + 1) * dh], out);
                }
            }

            // o proj + residual
            matmul_into(
                &self.attn_out,
                self.weights.layer("wo", layer),
                1,
                h * dh,
                d,
                &mut self.o,
            );
            for j in 0..d {
                self.x[j] += self.o[j];
            }
            // mlp
            rms_norm(&self.x, self.weights.layer("norm_mlp", layer), 1e-5, &mut self.xn);
            matmul_into(&self.xn, self.weights.layer("w_gate", layer), 1, d, cfg.ffn, &mut self.ffn_gate);
            matmul_into(&self.xn, self.weights.layer("w_up", layer), 1, d, cfg.ffn, &mut self.ffn_up);
            for j in 0..cfg.ffn {
                self.ffn_gate[j] = silu(self.ffn_gate[j]) * self.ffn_up[j];
            }
            matmul_into(&self.ffn_gate, self.weights.layer("w_down", layer), 1, cfg.ffn, d, &mut self.o);
            for j in 0..d {
                self.x[j] += self.o[j];
            }

            // stash this layer's k/v
            new_k[layer * kv * dh..(layer + 1) * kv * dh].copy_from_slice(&self.k);
            new_v[layer * kv * dh..(layer + 1) * kv * dh].copy_from_slice(&self.v);
        }

        rms_norm(&self.x, &self.weights.get("norm_final").data, 1e-5, &mut self.xn[..d]);
        matmul_into(
            &self.xn[..d],
            &self.weights.get("lm_head").data,
            1,
            d,
            cfg.vocab,
            &mut self.logits,
        );
        cache.append_step(&new_k, &new_v);
        &self.logits
    }

    /// [`Model::decode_step`] scored through the DRAFT LUT: identical
    /// layer stack and cache effects, but the quantized region is scored
    /// against the code-truncated coarse plane — the cheap proposal pass
    /// of speculative decoding.  Panics unless [`Model::set_draft`] ran.
    pub fn decode_step_draft(&mut self, token: u32, cache: &mut SequenceCache) -> &[f32] {
        let mut dl = self.draft_lut.take().expect("set_draft before decode_step_draft");
        std::mem::swap(&mut self.lut, &mut dl);
        let _ = self.decode_step(token, cache);
        std::mem::swap(&mut self.lut, &mut dl);
        self.draft_lut = Some(dl);
        &self.logits
    }

    /// One speculative GREEDY decode round: propose up to `k` tokens with
    /// the draft plane, verify them in one exact batched forward, emit
    /// the accepted prefix (plus the exact correction or bonus token),
    /// and append exactly the KV rows sequential decode would have fed.
    ///
    /// Bit-identity is by construction, not by luck:
    ///
    /// * the window is capped at the current group's residual headroom
    ///   (`group - resid_len`), so no page cut can land mid-window and
    ///   [`Model::verify_chunk`] scores the identical context sets as
    ///   sequential [`Model::decode_step`] calls;
    /// * drafting runs on a throwaway COW [`SequenceCache::fork`] (pages
    ///   Arc-shared, fp tails deep-copied) — dropping the fork IS the
    ///   rollback, reconciling pool accounting via `Drop`;
    /// * emission stops exactly where sequential decode would: at the
    ///   first verification mismatch (emitting the exact argmax
    ///   correction), at the first stop token, and at the generation
    ///   budget (`max_emit`); the last emitted token stays unfed, so the
    ///   engine's `fed + 1 == generated` invariant survives bursts.
    ///
    /// Falls back to a plain [`Model::decode_step`] when the window
    /// cannot fit two positions (group boundary, budget, or k == 0).
    pub fn speculative_decode(
        &mut self,
        last_token: u32,
        cache: &mut SequenceCache,
        k: usize,
        max_emit: usize,
        stop_tokens: &[u32],
        want_logprob: bool,
    ) -> SpecDecode {
        debug_assert!(max_emit >= 1);
        let group = self.cfg.group;
        let resid = cache.len() - cache.quantized_len();
        let w = (k + 1).min(max_emit).min(group.saturating_sub(resid));
        if w < 2 || self.draft_lut.is_none() {
            let logits = self.decode_step(last_token, cache);
            let tok = argmax(logits) as u32;
            let lp = if want_logprob { logprob_at(logits, tok as usize) } else { 0.0 };
            return SpecDecode { tokens: vec![(tok, lp)], drafted: 0, accepted: 0 };
        }

        // 1) propose: w-1 greedy draft steps on a throwaway fork.  The
        // fork's appends stay inside the group's residual headroom too
        // (resid + w - 1 < group), so it never cuts a page — dropping it
        // releases only deep-copied fp tails.
        let mut feeds = Vec::with_capacity(w);
        feeds.push(last_token);
        {
            let mut draft_cache = cache.fork();
            let mut cur = last_token;
            for _ in 1..w {
                let logits = self.decode_step_draft(cur, &mut draft_cache);
                cur = argmax(logits) as u32;
                feeds.push(cur);
            }
        } // <- rollback: rejected drafts unwind with the fork

        // 2) verify: one exact batched forward over the whole window
        let (all_logits, k_all, v_all) = self.verify_chunk(&feeds, cache);

        // 3) accept the longest prefix where the exact greedy choice
        // matches the next draft; the first mismatch emits the exact
        // correction instead, a fully-matched window emits a bonus token
        let mut emitted: Vec<(u32, f32)> = Vec::with_capacity(w);
        for (i, logits) in all_logits.iter().enumerate() {
            let tok = argmax(logits) as u32;
            let lp = if want_logprob { logprob_at(logits, tok as usize) } else { 0.0 };
            emitted.push((tok, lp));
            if i + 1 >= w || feeds[i + 1] != tok {
                break;
            }
        }
        let accepted = (emitted.len() - 1) as u32;

        // 4) clamp exactly where sequential decode would have stopped
        if let Some(stop_at) = emitted.iter().position(|(t, _)| stop_tokens.contains(t)) {
            emitted.truncate(stop_at + 1);
        }
        emitted.truncate(max_emit);

        // 5) append KV for feeds[0..e] — the rows sequential decode would
        // have fed.  Row-by-row append keeps the page-cut timing (at most
        // one, at the window's end) identical to sequential decode.
        let e = emitted.len();
        let (l_n, kvh, dh) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        let mut row_k = vec![0.0f32; l_n * kvh * dh];
        let mut row_v = vec![0.0f32; l_n * kvh * dh];
        for n in 0..e {
            for layer in 0..l_n {
                for head in 0..kvh {
                    let src = ((layer * kvh + head) * w + n) * dh;
                    let dst = (layer * kvh + head) * dh;
                    row_k[dst..dst + dh].copy_from_slice(&k_all[src..src + dh]);
                    row_v[dst..dst + dh].copy_from_slice(&v_all[src..src + dh]);
                }
            }
            cache.append_step(&row_k, &row_v);
        }

        if let Some(tr) = &self.trace {
            tr.record(
                self.trace_req,
                TraceKind::SpeculativeRound { drafted: (w - 1) as u32, accepted },
            );
        }
        SpecDecode { tokens: emitted, drafted: (w - 1) as u32, accepted }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::tiny();
        cfg.n_layers = 2;
        cfg.vocab = 64;
        cfg.d_model = 32;
        cfg.n_heads = 4;
        cfg.n_kv_heads = 2;
        cfg.head_dim = 16;
        cfg.ffn = 48;
        cfg.group = 8;
        cfg.resid = 16;
        cfg
    }

    #[test]
    fn decode_over_residual_matches_prefill() {
        // With bits high enough that nothing is quantized yet (prompt <
        // group), decode of token T must equal prefill logits over T+1.
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 5, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(17);
        let toks: Vec<u32> = (0..7).map(|_| rng.below(cfg.vocab) as u32).collect();
        let next: u32 = rng.below(cfg.vocab) as u32;

        let mut cache = SequenceCache::new(cfg.cache_config(None));
        let _ = model.prefill(&toks, &mut cache);
        assert_eq!(cache.quantized_len(), 0, "7 < group=8: all residual");
        let got = model.decode_step(next, &mut cache).to_vec();

        let mut full: Vec<u32> = toks.clone();
        full.push(next);
        let mut cache2 = SequenceCache::new(cfg.cache_config(None));
        let want = model.prefill(&full, &mut cache2);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 2e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn quantized_decode_stays_close_to_fp() {
        // Once groups quantize, logits drift but must stay close at 4/4
        // bits (the paper's near-lossless claim, natively).
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 6, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(18);
        let toks: Vec<u32> = (0..20).map(|_| rng.below(cfg.vocab) as u32).collect();
        let next = 3u32;

        let mut cache = SequenceCache::new(cfg.cache_config(None));
        model.prefill(&toks, &mut cache);
        assert_eq!(cache.quantized_len(), 16);
        let got = model.decode_step(next, &mut cache).to_vec();

        let mut full = toks.clone();
        full.push(next);
        let mut cache2 = SequenceCache::new(cfg.cache_config(None));
        let want = model.prefill(&full, &mut cache2);
        let cos = crate::tensor::ops::cosine(&got, &want);
        // toy geometry (dh=16, group=8) quantizes coarser than the paper's
        // d=128/g=128 setting; direction must still be preserved…
        assert!(cos > 0.95, "cos {cos}");
        // …and the fp argmax must stay in the quantized model's top-3
        // (strict argmax equality is seed-dependent at toy scale).
        let want_top = argmax(&want);
        let mut idx: Vec<usize> = (0..got.len()).collect();
        idx.sort_by(|&a, &b| got[b].partial_cmp(&got[a]).unwrap());
        assert!(idx[..3].contains(&want_top), "fp argmax {want_top} not in top-3 {:?}", &idx[..3]);
    }

    #[test]
    fn decode_steps_advance_cache() {
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 7, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut cache = SequenceCache::new(cfg.cache_config(None));
        model.prefill(&[1, 2, 3], &mut cache);
        for i in 0..10 {
            model.decode_step(i % cfg.vocab as u32, &mut cache);
        }
        assert_eq!(cache.len(), 13);
        assert_eq!(cache.next_pos, 13);
        assert_eq!(cache.quantized_len(), 8);
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_unchunked() {
        // Exact-mode chunked prefill (deferred finalization) must
        // reproduce Model::prefill bit-for-bit: same logits at the last
        // prompt position, same quantized groups, same residual — at ANY
        // chunk size, including chunk=1 and chunk > prompt.
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 21, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(31);
        let toks: Vec<u32> = (0..23).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut c_ref = SequenceCache::new(cfg.cache_config(None));
        let want = model.prefill(&toks, &mut c_ref);
        for chunk in [1usize, 3, 8, 23, 40] {
            let mut c = SequenceCache::new(cfg.cache_config(None));
            let mut got = Vec::new();
            let mut pos = 0;
            while pos < toks.len() {
                let take = chunk.min(toks.len() - pos);
                let last = pos + take == toks.len();
                let l = model.prefill_chunk(&toks[pos..pos + take], pos, &mut c, false, last);
                assert_eq!(l.is_empty(), !last, "logits only on the final chunk");
                got = l;
                pos += take;
            }
            c.flush_groups();
            assert_eq!(got, want, "chunk={chunk}: last-position logits differ");
            assert_eq!(c.next_pos, c_ref.next_pos);
            assert_eq!(c.quantized_len(), c_ref.quantized_len(), "chunk={chunk}");
            for l in 0..cfg.n_layers {
                for h in 0..cfg.n_kv_heads {
                    let a = c.stream(l, h);
                    let b = c_ref.stream(l, h);
                    assert_eq!(a.decode_keys(), b.decode_keys(), "chunk={chunk}: keys");
                    assert_eq!(a.resid_k(), b.resid_k(), "chunk={chunk}: resid_k");
                    assert_eq!(a.resid_v(), b.resid_v(), "chunk={chunk}: resid_v");
                }
            }
        }
    }

    #[test]
    fn single_token_chunk_matches_decode_step_over_quantized_cache() {
        // Eager mode against a cache holding quantized groups exercises
        // the LUT + residual + in-chunk mixed path; a 1-token chunk is
        // exactly one decode step, so the logits must agree bit-for-bit.
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 22, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(32);
        let toks: Vec<u32> = (0..20).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut cache = SequenceCache::new(cfg.cache_config(Some(4)));
        model.prefill(&toks, &mut cache);
        assert!(cache.quantized_len() > 0, "need quantized groups for the LUT path");
        let mut c2 = cache.clone();
        let want = model.decode_step(9, &mut cache).to_vec();
        let got = model.prefill_chunk(&[9], 20, &mut c2, true, true);
        assert_eq!(got, want);
        assert_eq!(c2.len(), cache.len());
        assert_eq!(c2.quantized_len(), cache.quantized_len());
    }

    #[test]
    fn eager_chunked_prefill_stays_close_to_exact() {
        // Eager finalization scores later chunks against quantized keys —
        // not bit-identical, but within the paper's near-lossless drift.
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 23, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(33);
        let toks: Vec<u32> = (0..24).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut c_ref = SequenceCache::new(cfg.cache_config(None));
        let want = model.prefill(&toks, &mut c_ref);
        let mut c = SequenceCache::new(cfg.cache_config(None));
        let mut got = Vec::new();
        let n_chunks = toks.chunks(8).count();
        for (ci, ch) in toks.chunks(8).enumerate() {
            got = model.prefill_chunk(ch, ci * 8, &mut c, true, ci + 1 == n_chunks);
        }
        assert_eq!(c.quantized_len(), 24, "eager chunks finalized groups mid-prefill");
        let cos = crate::tensor::ops::cosine(&got, &want);
        assert!(cos > 0.95, "cos {cos}");
    }

    #[test]
    fn verify_chunk_matches_sequential_decode_bitwise() {
        // The foundation of speculative decoding: a verification window
        // that fits the residual headroom scores every position
        // bit-identically to sequential decode steps, and appends nothing.
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 42, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(52);
        let toks: Vec<u32> = (0..20).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut cache = SequenceCache::new(cfg.cache_config(Some(4)));
        model.prefill(&toks, &mut cache);
        assert_eq!(cache.quantized_len(), 16, "LUT path must be exercised");
        // resid 4 of group 8: a 4-token window exactly fills the headroom
        let feeds = [3u32, 9, 1, 7];
        let before = cache.next_pos;
        let (all, k_all, v_all) = model.verify_chunk(&feeds, &cache);
        assert_eq!(all.len(), feeds.len());
        assert_eq!(cache.next_pos, before, "verify appends nothing");
        assert_eq!(k_all.len(), cfg.n_layers * cfg.n_kv_heads * feeds.len() * cfg.head_dim);
        assert_eq!(v_all.len(), k_all.len());
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let mut c2 = cache.clone();
        for (i, &f) in feeds.iter().enumerate() {
            let want = model.decode_step(f, &mut c2).to_vec();
            assert_eq!(bits(&all[i]), bits(&want), "position {i}");
        }
    }

    #[test]
    fn speculative_greedy_decode_is_bit_identical_to_sequential() {
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 41, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        model.set_draft(crate::quant::DraftSpec::new(2, 2)).unwrap();
        let mut rng = Rng::new(51);
        let toks: Vec<u32> = (0..13).map(|_| rng.below(cfg.vocab) as u32).collect();

        // sequential greedy reference, 25 tokens
        let mut c_seq = SequenceCache::new(cfg.cache_config(None));
        let logits = model.prefill(&toks, &mut c_seq);
        let mut seq_tokens = vec![argmax(&logits) as u32];
        for _ in 0..24 {
            let last = *seq_tokens.last().unwrap();
            let l = model.decode_step(last, &mut c_seq).to_vec();
            seq_tokens.push(argmax(&l) as u32);
        }

        // speculative rollout of the same length, windows crossing
        // several group boundaries (group 8)
        let mut c_spec = SequenceCache::new(cfg.cache_config(None));
        let logits = model.prefill(&toks, &mut c_spec);
        let mut spec_tokens = vec![argmax(&logits) as u32];
        let (mut drafted, mut accepted) = (0u32, 0u32);
        while spec_tokens.len() < seq_tokens.len() {
            let last = *spec_tokens.last().unwrap();
            let max_emit = seq_tokens.len() - spec_tokens.len();
            let out = model.speculative_decode(last, &mut c_spec, 3, max_emit, &[], false);
            assert!(!out.tokens.is_empty());
            drafted += out.drafted;
            accepted += out.accepted;
            spec_tokens.extend(out.tokens.iter().map(|(t, _)| *t));
        }
        assert_eq!(spec_tokens, seq_tokens, "speculative greedy must be bit-identical");
        assert!(drafted >= accepted);
        // the 2-bit draft tracks the 4-bit plane closely at toy scale;
        // zero acceptance would defeat the feature (CI smokes this
        // end-to-end on the serve path too)
        assert!(accepted > 0, "drafted {drafted}, accepted {accepted}");
        // final cache state identical to the sequential rollout
        assert_eq!(c_spec.next_pos, c_seq.next_pos);
        assert_eq!(c_spec.quantized_len(), c_seq.quantized_len());
        for l in 0..cfg.n_layers {
            for h in 0..cfg.n_kv_heads {
                let a = c_spec.stream(l, h);
                let b = c_seq.stream(l, h);
                assert_eq!(a.decode_keys(), b.decode_keys(), "layer {l} head {h}");
                assert_eq!(a.resid_k(), b.resid_k(), "layer {l} head {h}");
                assert_eq!(a.resid_v(), b.resid_v(), "layer {l} head {h}");
            }
        }
    }

    #[test]
    fn exact_width_draft_accepts_every_draft() {
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 44, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        // draft == exact plane: the proposal pass replays the exact path
        // bit-for-bit, so verification must accept every draft and emit
        // the bonus token
        model.set_draft(crate::quant::DraftSpec::new(4, 4)).unwrap();
        let toks: Vec<u32> = (0..20).map(|i| ((i * 7) % cfg.vocab) as u32).collect();
        let mut cache = SequenceCache::new(cfg.cache_config(None));
        let l = model.prefill(&toks, &mut cache);
        let last = argmax(&l) as u32;
        let out = model.speculative_decode(last, &mut cache, 3, 100, &[], false);
        assert_eq!(out.drafted, 3, "resid 4 + window 4 fits the group exactly");
        assert_eq!(out.accepted, 3, "an exact-width draft is never rejected");
        assert_eq!(out.tokens.len(), 4, "3 accepted + the bonus token");
    }

    #[test]
    fn speculative_window_respects_group_boundary() {
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 43, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        model.set_draft(crate::quant::DraftSpec::new(2, 2)).unwrap();
        let toks: Vec<u32> = (0..20).map(|i| (i % cfg.vocab) as u32).collect();
        let mut cache = SequenceCache::new(cfg.cache_config(None));
        model.prefill(&toks, &mut cache); // resid 4 of group 8
        let out = model.speculative_decode(1, &mut cache, 8, 100, &[], false);
        assert_eq!(out.drafted, 3, "window capped at the group headroom (4)");
        // walk the residual up to group-1: headroom 1 forces the fallback
        while cache.len() - cache.quantized_len() != cfg.group - 1 {
            model.decode_step(0, &mut cache);
        }
        let out = model.speculative_decode(1, &mut cache, 8, 100, &[], false);
        assert_eq!(out.drafted, 0, "no room for a window: plain decode step");
        assert_eq!(out.tokens.len(), 1);
        // a 1-token generation budget also falls back
        let out = model.speculative_decode(1, &mut cache, 8, 1, &[], false);
        assert_eq!(out.drafted, 0);
        assert_eq!(out.tokens.len(), 1);
    }

    #[test]
    fn speculative_decode_clamps_at_stop_tokens() {
        // A stop token among the accepted drafts must end the burst
        // exactly where sequential decode would have finished.
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 44, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        model.set_draft(crate::quant::DraftSpec::new(4, 4)).unwrap();
        let toks: Vec<u32> = (0..20).map(|i| ((i * 7) % cfg.vocab) as u32).collect();
        let mut cache = SequenceCache::new(cfg.cache_config(None));
        let l = model.prefill(&toks, &mut cache);
        let last = argmax(&l) as u32;
        // dry-run (exact-width draft accepts everything) to learn the
        // tokens, then replay with the second emission as a stop token
        let probe = model.speculative_decode(last, &mut cache.clone(), 3, 100, &[], false);
        assert_eq!(probe.tokens.len(), 4);
        let stop = probe.tokens[1].0;
        let out = model.speculative_decode(last, &mut cache, 3, 100, &[stop], false);
        let emitted: Vec<u32> = out.tokens.iter().map(|(t, _)| *t).collect();
        let probed: Vec<u32> = probe.tokens.iter().map(|(t, _)| *t).collect();
        // sequential decode would stop at the FIRST occurrence of `stop`
        // (inclusive) — synthetic-weight rollouts may repeat tokens, so
        // find it rather than assuming index 1
        let cut = probed.iter().position(|&t| t == stop).unwrap() + 1;
        assert!(cut < probed.len(), "clamp must shorten the burst");
        assert_eq!(emitted, probed[..cut].to_vec(), "burst clamped at the stop token");
        // KV rows follow the clamped emission: feeds[0..cut] were appended
        assert_eq!(cache.len(), 20 + cut);
    }

    #[test]
    fn quantized_values_barely_move_logits() {
        let cfg = test_cfg();
        let w = Weights::synthetic(&cfg, 8, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        let mut rng = Rng::new(19);
        let toks: Vec<u32> = (0..24).map(|_| rng.below(cfg.vocab) as u32).collect();

        let mut c_fp = SequenceCache::new(cfg.cache_config(None));
        model.prefill(&toks, &mut c_fp);
        let a = model.decode_step(1, &mut c_fp).to_vec();

        let mut c_q = SequenceCache::new(cfg.cache_config(Some(4)));
        model.prefill(&toks, &mut c_q);
        let b = model.decode_step(1, &mut c_q).to_vec();
        let cos = crate::tensor::ops::cosine(&a, &b);
        assert!(cos > 0.99, "cos {cos}");
    }
}
