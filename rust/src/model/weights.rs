//! Weight loading: `weights_<cfg>.bin` (raw little-endian f32, manifest
//! tensor table) and synthetic in-memory initialization for tests/benches
//! that must not depend on artifacts.

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::json::Value;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Weights {
    tensors: HashMap<String, Tensor>,
}

/// Canonical tensor order/shapes (mirrors `model.weight_specs`).
pub fn weight_specs(cfg: &ModelConfig) -> Vec<(&'static str, Vec<usize>)> {
    let (l, d, h, kv, dh, f, v) = (
        cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.ffn, cfg.vocab,
    );
    vec![
        ("embed", vec![v, d]),
        ("wq", vec![l, d, h * dh]),
        ("wk", vec![l, d, kv * dh]),
        ("bk", vec![l, kv * dh]),
        ("wv", vec![l, d, kv * dh]),
        ("wo", vec![l, h * dh, d]),
        ("w_gate", vec![l, d, f]),
        ("w_up", vec![l, d, f]),
        ("w_down", vec![l, f, d]),
        ("norm_attn", vec![l, d]),
        ("norm_mlp", vec![l, d]),
        ("norm_final", vec![d]),
        ("lm_head", vec![d, v]),
    ]
}

impl Weights {
    /// Load from the artifact .bin using the manifest's tensor table.
    pub fn load(path: &Path, manifest_weights: &Value, cfg: &ModelConfig) -> Result<Self> {
        let mut file = std::fs::File::open(path)
            .with_context(|| format!("opening weights file {path:?}"))?;
        let mut raw = Vec::new();
        file.read_to_end(&mut raw)?;
        let table = manifest_weights
            .req("tensors")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("weights.tensors not an array")?;
        let mut tensors = HashMap::new();
        for entry in table {
            let name = entry.str_or("name", "");
            let shape = entry
                .req("shape")
                .map_err(anyhow::Error::msg)?
                .usize_vec()
                .context("bad shape")?;
            let offset = entry.usize_or("offset_bytes", usize::MAX);
            let size = entry.usize_or("size_bytes", 0);
            if offset == usize::MAX || offset + size > raw.len() {
                bail!("tensor {name}: bad offset/size");
            }
            let n = size / 4;
            let mut data = vec![0.0f32; n];
            for i in 0..n {
                let b = &raw[offset + 4 * i..offset + 4 * i + 4];
                data[i] = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
            tensors.insert(name, Tensor::new(data, &shape));
        }
        // sanity: every expected tensor present with the expected shape
        for (name, shape) in weight_specs(cfg) {
            let t = tensors
                .get(name)
                .with_context(|| format!("weights missing tensor '{name}'"))?;
            if t.shape != shape {
                bail!("tensor {name}: shape {:?} != expected {:?}", t.shape, shape);
            }
        }
        Ok(Weights { tensors })
    }

    /// Synthetic weights with the paper's key-channel outlier structure
    /// (mirrors `model.init_weights`; NOT bit-identical to numpy — use the
    /// artifact .bin when cross-checking against the PJRT graphs).
    pub fn synthetic(cfg: &ModelConfig, seed: u64, outlier_severity: f32) -> Self {
        let mut rng = Rng::new(seed);
        let mut tensors = HashMap::new();
        for (name, shape) in weight_specs(cfg) {
            let n: usize = shape.iter().product();
            let data = if name.starts_with("norm") {
                vec![1.0f32; n]
            } else if name == "bk" {
                vec![0.0f32; n]
            } else {
                let fan_in = if shape.len() >= 2 { shape[shape.len() - 2] } else { shape[0] };
                let std = 1.0 / (fan_in as f32).sqrt();
                let mut v = rng.normal_vec(n);
                for x in v.iter_mut() {
                    *x *= std;
                }
                v
            };
            tensors.insert(name.to_string(), Tensor::new(data, &shape));
        }
        // Channel outliers via a constant key BIAS on one dim of some
        // RoPE pairs (Qwen-style attention bias — the paper's hardest
        // case): post-RoPE those pairs trace the Figure-1(b) ring
        // (consistent radius, smooth angle) while their Cartesian
        // magnitudes dwarf other channels on every token (Figure 1a).
        // Mirrors python/compile/model.py::init_weights.
        let dh = cfg.head_dim;
        let n_pairs = dh / 2;
        let n_out = (n_pairs / 16).max(1);
        let bk = tensors.get_mut("bk").unwrap();
        let kv = cfg.n_kv_heads;
        if outlier_severity > 0.0 {
            for l in 0..cfg.n_layers {
                for h in 0..kv {
                    let pairs = rng.choose_distinct(n_pairs, n_out);
                    for j in pairs {
                        let sign = rng.sign();
                        bk.data[(l * kv + h) * dh + 2 * j] = sign * outlier_severity;
                    }
                }
            }
        }
        Weights { tensors }
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("missing weight tensor '{name}'"))
    }

    /// Layer slice of a stacked (L, a, b) tensor as a flat &[f32] (a*b).
    pub fn layer<'a>(&'a self, name: &str, layer: usize) -> &'a [f32] {
        let t = self.get(name);
        assert!(t.rank() >= 2);
        let per = t.numel() / t.shape[0];
        &t.data[layer * per..(layer + 1) * per]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_has_all_tensors() {
        let cfg = ModelConfig::tiny();
        let w = Weights::synthetic(&cfg, 0, 6.0);
        for (name, shape) in weight_specs(&cfg) {
            assert_eq!(w.get(name).shape, shape, "{name}");
        }
    }

    #[test]
    fn outliers_present_in_wk() {
        let cfg = ModelConfig::tiny();
        let plain = Weights::synthetic(&cfg, 0, 0.0);
        let spiky = Weights::synthetic(&cfg, 0, 20.0);
        let max_plain = plain.get("bk").data.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let max_spiky = spiky.get("bk").data.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        assert_eq!(max_plain, 0.0);
        assert_eq!(max_spiky, 20.0);
    }

    #[test]
    fn layer_slicing() {
        let cfg = ModelConfig::tiny();
        let w = Weights::synthetic(&cfg, 1, 6.0);
        let wq = w.get("wq");
        let per = wq.numel() / cfg.n_layers;
        assert_eq!(w.layer("wq", 2), &wq.data[2 * per..3 * per]);
    }
}
