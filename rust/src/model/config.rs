//! Model configuration — parsed from `artifacts/manifest.json` (the single
//! source of truth emitted by `python/compile/aot.py`).

use anyhow::{Context, Result};

use crate::quant::polar::PolarSpec;
use crate::util::json::Value;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub rope_base: f32,
    pub group: usize,
    pub r_bits: u32,
    pub t_bits: u32,
    pub resid: usize,
}

impl ModelConfig {
    pub fn q_per_kv(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn polar_spec(&self) -> PolarSpec {
        PolarSpec::new(self.r_bits, self.t_bits, self.group)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let req_usize = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("manifest config missing '{k}'"))
        };
        Ok(ModelConfig {
            name: v.str_or("name", "unknown"),
            vocab: req_usize("vocab")?,
            d_model: req_usize("d_model")?,
            n_layers: req_usize("n_layers")?,
            n_heads: req_usize("n_heads")?,
            n_kv_heads: req_usize("n_kv_heads")?,
            head_dim: req_usize("head_dim")?,
            ffn: req_usize("ffn")?,
            rope_base: v.f64_or("rope_base", 10000.0) as f32,
            group: req_usize("group")?,
            r_bits: req_usize("r_bits")? as u32,
            t_bits: req_usize("t_bits")? as u32,
            resid: req_usize("resid")?,
        })
    }

    /// The canonical test config (mirrors `CONFIGS["tiny"]` in model.py).
    pub fn tiny() -> Self {
        ModelConfig {
            name: "tiny".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 32,
            ffn: 256,
            rope_base: 10000.0,
            group: 64,
            r_bits: 4,
            t_bits: 4,
            resid: 64,
        }
    }

    /// Llama-3.1-8B attention geometry (32 q-heads / 8 kv-heads, d=128) at
    /// reduced depth — what the paper's kernel benches (Fig 3) run on.
    pub fn llama31_head() -> Self {
        ModelConfig {
            name: "llama31-head".into(),
            vocab: 1024,
            d_model: 512,
            n_layers: 2,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            ffn: 1024,
            rope_base: 500000.0,
            group: 128,
            r_bits: 4,
            t_bits: 4,
            resid: 128,
        }
    }

    pub fn cache_config(&self, value_bits: Option<u32>) -> crate::kvcache::CacheConfig {
        crate::kvcache::CacheConfig {
            n_layers: self.n_layers,
            n_kv_heads: self.n_kv_heads,
            head_dim: self.head_dim,
            spec: self.polar_spec(),
            value_bits,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn parses_manifest_config() {
        let text = r#"{"name": "tiny", "vocab": 512, "d_model": 128,
            "n_layers": 4, "n_heads": 4, "n_kv_heads": 2, "head_dim": 32,
            "ffn": 256, "rope_base": 10000.0, "group": 64, "r_bits": 4,
            "t_bits": 4, "resid": 64}"#;
        let v = json::parse(text).unwrap();
        let cfg = ModelConfig::from_json(&v).unwrap();
        assert_eq!(cfg, ModelConfig::tiny());
        assert_eq!(cfg.q_per_kv(), 2);
    }

    #[test]
    fn missing_field_is_an_error() {
        let v = json::parse(r#"{"vocab": 10}"#).unwrap();
        assert!(ModelConfig::from_json(&v).is_err());
    }
}
