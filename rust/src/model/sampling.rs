//! Token sampling: greedy and stochastic (temperature + top-k + top-p),
//! with per-request reproducibility.
//!
//! Sampling is driven by a PER-TOKEN derived RNG ([`token_rng`]): the
//! stream for token `i` of a request is a pure function of the request's
//! `GenOptions::seed` and `i`, never of which decode worker ran the step
//! or of any engine-global RNG state.  That makes sampled rollouts
//! bit-identical across decode-pool widths, across preemption/replay
//! recovery, and across engine restarts — the property the streaming API
//! advertises and the proptests pin down.

use crate::tensor::ops::argmax;
use crate::util::rng::Rng;

/// RNG for the `index`-th generated token of a request seeded `seed`.
/// Derivation goes through SplitMix64 (inside [`Rng::new`]), so nearby
/// (seed, index) pairs give uncorrelated streams.
pub fn token_rng(seed: u64, index: usize) -> Rng {
    Rng::new(seed ^ (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Log-probability of `tok` under the full softmax of `logits`
/// (temperature-independent: the model's own distribution, which is what
/// the streaming `token` events report).
pub fn logprob_at(logits: &[f32], tok: usize) -> f32 {
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = logits.iter().map(|&l| (l - mx).exp()).sum();
    logits[tok] - mx - lse.ln()
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampler {
    Greedy,
    /// softmax(logits / temperature) restricted to the top-k entries
    /// (`top_k == 0` = full vocab) and then to the smallest nucleus whose
    /// probability mass reaches `top_p` (`top_p >= 1.0` = off)
    Stochastic { temperature: f32, top_k: usize, top_p: f32 },
}

impl Sampler {
    /// Sample one token.  No logprob is computed — this is the hot path
    /// for requests nobody is streaming to (greedy = one argmax pass).
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        match *self {
            Sampler::Greedy => argmax(logits) as u32,
            Sampler::Stochastic { temperature, top_k, top_p } => {
                let k = if top_k == 0 { logits.len() } else { top_k.min(logits.len()) };
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                // stable sort: ties keep index order, so the candidate set
                // is deterministic for any logits
                idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
                idx.truncate(k.max(1));
                let t = temperature.max(1e-4);
                let mx = logits[idx[0]];
                let mut probs: Vec<f32> =
                    idx.iter().map(|&i| ((logits[i] - mx) / t).exp()).collect();
                let sum: f32 = probs.iter().sum();
                for p in probs.iter_mut() {
                    *p /= sum;
                }
                if top_p < 1.0 {
                    // probs are sorted descending (idx is); keep the
                    // smallest prefix reaching the nucleus mass
                    let p_cap = top_p.max(0.0);
                    let mut cum = 0.0f32;
                    let mut keep = probs.len();
                    for (j, &p) in probs.iter().enumerate() {
                        cum += p;
                        if cum >= p_cap {
                            keep = j + 1;
                            break;
                        }
                    }
                    probs.truncate(keep);
                    idx.truncate(keep);
                    let s: f32 = probs.iter().sum();
                    for p in probs.iter_mut() {
                        *p /= s;
                    }
                }
                let mut u = rng.uniform() as f32;
                let mut chosen = idx[idx.len() - 1];
                for (j, &p) in probs.iter().enumerate() {
                    if u < p {
                        chosen = idx[j];
                        break;
                    }
                    u -= p;
                }
                chosen as u32
            }
        }
    }

    /// Sample one token and return it with its full-softmax logprob
    /// (two extra O(vocab) passes — only worth paying when a subscriber
    /// will actually see the token event).
    pub fn sample_with_logprob(&self, logits: &[f32], rng: &mut Rng) -> (u32, f32) {
        let tok = self.sample(logits, rng);
        (tok, logprob_at(logits, tok as usize))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Rng::new(1);
        let s = Sampler::Greedy;
        assert_eq!(s.sample(&[0.1, 3.0, 1.0], &mut rng), 1);
    }

    #[test]
    fn topk_stays_in_topk() {
        let mut rng = Rng::new(2);
        let s = Sampler::Stochastic { temperature: 1.0, top_k: 2, top_p: 1.0 };
        let logits = [0.0, 5.0, 4.0, -10.0];
        for _ in 0..100 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 1 || t == 2, "sampled {t}");
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut rng = Rng::new(3);
        let s = Sampler::Stochastic { temperature: 1e-3, top_k: 4, top_p: 1.0 };
        let logits = [0.0, 5.0, 4.9, -1.0];
        let mut ones = 0;
        for _ in 0..200 {
            if s.sample(&logits, &mut rng) == 1 {
                ones += 1;
            }
        }
        assert!(ones > 190, "{ones}");
    }

    #[test]
    fn top_p_restricts_to_nucleus() {
        let mut rng = Rng::new(4);
        // p(1) ~ 0.73, p(2) ~ 0.27 at temp 1 within top-2; a 0.5 nucleus
        // keeps only index 1
        let s = Sampler::Stochastic { temperature: 1.0, top_k: 0, top_p: 0.5 };
        let logits = [0.0, 5.0, 4.0, -10.0];
        for _ in 0..100 {
            assert_eq!(s.sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn token_rng_is_a_pure_function_of_seed_and_index() {
        for seed in [0u64, 7, 991] {
            for idx in [0usize, 1, 63] {
                let mut a = token_rng(seed, idx);
                let mut b = token_rng(seed, idx);
                assert_eq!(a.next_u64(), b.next_u64());
            }
        }
        // different indices give different streams
        let mut a = token_rng(5, 0);
        let mut b = token_rng(5, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn logprob_is_log_softmax() {
        let logits = [1.0f32, 2.0, 3.0];
        let z: f32 = logits.iter().map(|l| l.exp()).sum();
        for (i, &l) in logits.iter().enumerate() {
            let want = (l.exp() / z).ln();
            assert!((logprob_at(&logits, i) - want).abs() < 1e-5);
        }
        // probabilities sum to 1
        let total: f32 = (0..3).map(|i| logprob_at(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }
}
