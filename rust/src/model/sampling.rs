//! Token sampling: greedy, temperature, top-k.

use crate::tensor::ops::argmax;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampler {
    Greedy,
    /// softmax(logits / temperature) restricted to the top-k entries
    TopK { k: usize, temperature: f32 },
}

impl Sampler {
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        match *self {
            Sampler::Greedy => argmax(logits) as u32,
            Sampler::TopK { k, temperature } => {
                let k = k.max(1).min(logits.len());
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
                idx.truncate(k);
                let t = temperature.max(1e-4);
                let mx = logits[idx[0]];
                let mut probs: Vec<f32> =
                    idx.iter().map(|&i| ((logits[i] - mx) / t).exp()).collect();
                let sum: f32 = probs.iter().sum();
                for p in probs.iter_mut() {
                    *p /= sum;
                }
                let mut u = rng.uniform() as f32;
                for (j, &p) in probs.iter().enumerate() {
                    if u < p {
                        return idx[j] as u32;
                    }
                    u -= p;
                }
                idx[k - 1] as u32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Rng::new(1);
        let s = Sampler::Greedy;
        assert_eq!(s.sample(&[0.1, 3.0, 1.0], &mut rng), 1);
    }

    #[test]
    fn topk_stays_in_topk() {
        let mut rng = Rng::new(2);
        let s = Sampler::TopK { k: 2, temperature: 1.0 };
        let logits = [0.0, 5.0, 4.0, -10.0];
        for _ in 0..100 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 1 || t == 2, "sampled {t}");
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut rng = Rng::new(3);
        let s = Sampler::TopK { k: 4, temperature: 1e-3 };
        let logits = [0.0, 5.0, 4.9, -1.0];
        let mut ones = 0;
        for _ in 0..200 {
            if s.sample(&logits, &mut rng) == 1 {
                ones += 1;
            }
        }
        assert!(ones > 190, "{ones}");
    }
}
