//! Rust-native twin of the L2 JAX model (`python/compile/model.py`).
//!
//! Same architecture, same numerics (f32, RMS-norm eps 1e-5, adjacent-pair
//! RoPE, SwiGLU), consuming the same `weights_<cfg>.bin` artifact — so the
//! native backend and the PJRT backend are interchangeable inside the
//! engine and cross-checkable in integration tests.  Decode attention runs
//! over the quantized [`crate::kvcache::SequenceCache`] through the
//! PolarQuant LUT path — the Rust-level realization of the paper's
//! accelerated kernel.

pub mod config;
pub mod forward;
pub mod sampling;
pub mod weights;

pub use config::ModelConfig;
pub use forward::{Model, SpecDecode};
pub use weights::Weights;
