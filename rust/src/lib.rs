//! # PolarQuant — polar-transformation key-cache quantization + LUT decoding
//!
//! Reproduction of *"PolarQuant: Leveraging Polar Transformation for
//! Efficient Key Cache Quantization and Decoding Acceleration"* (Wu, Lv,
//! et al., 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): polar encoder and
//!   the fused LUT dequant+QK kernel, AOT-lowered to HLO text.
//! * **L2** — JAX transformer (`python/compile/model.py`): prefill and
//!   decode-step graphs over a PolarQuant-encoded key cache.
//! * **L3** — this crate: the serving coordinator (router, dynamic batcher,
//!   prefill/decode scheduler), the quantized paged KV-cache manager, the
//!   PJRT runtime that executes the AOT artifacts, a Rust-native reference
//!   model, every quantization baseline from the paper's evaluation
//!   (KIVI, Int-N, ZipCache, QJL), and the benchmark harnesses that
//!   regenerate each table/figure (see `DESIGN.md` §6).
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! graphs once; the `polarquant` binary is self-contained afterwards.
//!
//! ## Crate map
//!
//! | module | role |
//! |--------|------|
//! | [`tensor`] | minimal f32 tensor substrate (matmul, softmax, RoPE, norms) |
//! | [`quant`] | PolarQuant + every baseline codec, bit-packing, decode LUT |
//! | [`kvcache`] | paged quantized cache: refcounted group-page pool with prefix sharing + COW forks, residual buffers, eviction, exact O(1) memory accounting, shard-safe sequence handles |
//! | [`kvcache::tier`] | disk tier under the pool: versioned page serde + checksums, append-only segment store, background demotion / on-demand promotion, persistent prefix-cache snapshots |
//! | [`model`] | Rust-native twin of the L2 JAX model (config, shared weights, forward) |
//! | [`runtime`] | PJRT client (feature `pjrt`, stubbed offline), artifact manifest, layout marshalling, shape-bucket executors |
//! | [`coordinator`] | request router, dynamic batcher, chunked-prefill continuous-batching scheduler, streaming session engine (per-request `GenOptions`, token events, cancellation, multi-turn KV reuse), metrics |
//! | [`coordinator::pool`] | batched thread-parallel LUT decode: fixed worker pool, thread-local `QkLut` scratch, balanced cache-length shards (`benches/decode_batch.rs` tracks it) |
//! | [`server`] | JSON-lines TCP front-end + client (wire v1 one-shot + v2 streaming/cancel/session) |
//! | [`fabric`] | multi-node serving fabric: consistent-hash `route` front tier (placement, health/drain, hedging) + shared prefix-cache transfer over tier segments |
//! | [`trace`] | request-lifecycle tracing: bounded ring-buffer span recorder, Chrome `trace_event` export, Prometheus text exposition |
//! | [`workload`] | synthetic activation / request generators (outlier profiles) |
//! | [`eval`] | fidelity metrics, task proxies, paper-table printers |
//! | [`util`] | no-deps substrates: RNG, JSON codec, stats, bench harness |

pub mod coordinator;
pub mod eval;
pub mod fabric;
pub mod kvcache;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod trace;
pub mod util;
pub mod workload;
