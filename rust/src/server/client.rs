//! Minimal blocking client for the JSON-lines protocol (examples + tests
//! + the throughput bench's load generator).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

use crate::util::json::{self, num, obj, Value};

pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

#[derive(Clone, Debug)]
pub struct GenerateReply {
    pub id: u64,
    pub worker: usize,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub truncated: bool,
    /// the engine refused the request (backpressure / bad prompt); see
    /// `reason` — distinct from `truncated`, which ran but was cut short
    pub rejected: bool,
    pub reason: Option<String>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn generate(
        &mut self,
        prompt: &[u32],
        max_tokens: usize,
        session: Option<u64>,
    ) -> Result<GenerateReply> {
        let mut fields = vec![
            (
                "prompt",
                Value::Arr(prompt.iter().map(|&t| num(t as f64)).collect()),
            ),
            ("max_tokens", num(max_tokens as f64)),
        ];
        if let Some(s) = session {
            fields.push(("session", num(s as f64)));
        }
        writeln!(self.stream, "{}", json::write(&obj(fields)))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let v = json::parse(line.trim()).map_err(anyhow::Error::msg)?;
        if let Some(err) = v.get("error") {
            anyhow::bail!("server error: {:?}", err.as_str());
        }
        Ok(GenerateReply {
            id: v.usize_or("id", 0) as u64,
            worker: v.usize_or("worker", 0),
            prompt_len: v.usize_or("prompt_len", 0),
            tokens: v
                .get("tokens")
                .and_then(|t| t.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).map(|x| x as u32).collect())
                .unwrap_or_default(),
            ttft_ms: v.f64_or("ttft_ms", 0.0),
            total_ms: v.f64_or("total_ms", 0.0),
            truncated: v.get("truncated").and_then(|b| b.as_bool()).unwrap_or(false),
            rejected: v.get("rejected").and_then(|b| b.as_bool()).unwrap_or(false),
            reason: v.get("reason").and_then(|r| r.as_str()).map(|s| s.to_string()),
        })
    }

    fn admin(&mut self, cmd: &str) -> Result<Value> {
        writeln!(self.stream, "{}", json::write(&obj(vec![("admin", json::s(cmd))])))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let v = json::parse(line.trim()).map_err(anyhow::Error::msg)?;
        if let Some(err) = v.get("error") {
            anyhow::bail!("server error: {:?}", err.as_str());
        }
        Ok(v)
    }

    /// Fleet counters: per-worker objects under `"workers"` plus summed
    /// totals (`tier_hits`, `pages_demoted`, `prefix_hits`, ...) at the
    /// top level.
    pub fn metrics(&mut self) -> Result<Value> {
        self.admin("metrics")
    }

    /// Ask the server to drain, snapshot its tiers, and exit.
    pub fn shutdown(&mut self) -> Result<()> {
        self.admin("shutdown").map(|_| ())
    }
}
