//! Minimal blocking client for the JSON-lines protocol (examples + tests
//! + the throughput bench's load generator + the CLI `client` command).
//!
//! Speaks both wire versions: [`Client::generate`] is the v1 one-shot
//! request; [`Client::generate_stream`] / the session methods speak v2
//! (streaming frames, mid-stream cancel, session open / turn / close).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, num, obj, Value};

pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

/// Per-request generation options on the wire (all default to the greedy
/// v1 behavior; mirrors the engine's `GenOptions`).
#[derive(Clone, Debug)]
pub struct GenParams {
    pub max_tokens: usize,
    pub temperature: f64,
    pub top_k: usize,
    pub top_p: f64,
    pub seed: u64,
    pub stop: Vec<u32>,
    /// tenant identity for fair scheduling / quotas (v2 `tenant` field;
    /// empty = omitted, the server's shared `default` tenant)
    pub tenant: String,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_tokens: 16,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
            stop: Vec::new(),
            tenant: String::new(),
        }
    }
}

impl GenParams {
    pub fn greedy(max_tokens: usize) -> Self {
        GenParams { max_tokens, ..GenParams::default() }
    }

    /// The request-frame fields these options contribute (defaults are
    /// omitted so v1 frames stay byte-identical to the old client's).
    fn fields(&self, out: &mut Vec<(&'static str, Value)>) {
        out.push(("max_tokens", num(self.max_tokens as f64)));
        if self.temperature > 0.0 {
            out.push(("temperature", num(self.temperature)));
        }
        if self.top_k > 0 {
            out.push(("top_k", num(self.top_k as f64)));
        }
        if self.top_p < 1.0 {
            out.push(("top_p", num(self.top_p)));
        }
        if self.seed != 0 {
            // decimal string, not a JSON number: f64 rounds above 2^53,
            // which would silently change the seed (and the rollout)
            out.push(("seed", Value::Str(self.seed.to_string())));
        }
        if !self.stop.is_empty() {
            out.push(("stop", Value::Arr(self.stop.iter().map(|&t| num(t as f64)).collect())));
        }
        if !self.tenant.is_empty() {
            out.push(("tenant", json::s(&self.tenant)));
        }
    }
}

/// One streamed token (the v2 `token` frame).
#[derive(Clone, Copy, Debug)]
pub struct TokenEvent {
    pub id: u64,
    pub token: u32,
    pub logprob: f64,
    pub index: usize,
}

#[derive(Clone, Debug)]
pub struct GenerateReply {
    pub id: u64,
    pub worker: usize,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub truncated: bool,
    /// the engine refused the request (backpressure / bad prompt); see
    /// `reason` — distinct from `truncated`, which ran but was cut short
    pub rejected: bool,
    pub reason: Option<String>,
    /// why generation stopped: "stop" | "length" | "cancelled" |
    /// "rejected" (empty on pre-streaming servers)
    pub finish_reason: String,
}

impl GenerateReply {
    fn from_value(v: &Value) -> Self {
        GenerateReply {
            id: v.usize_or("id", 0) as u64,
            worker: v.usize_or("worker", 0),
            prompt_len: v.usize_or("prompt_len", 0),
            tokens: v
                .get("tokens")
                .and_then(|t| t.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).map(|x| x as u32).collect())
                .unwrap_or_default(),
            ttft_ms: v.f64_or("ttft_ms", 0.0),
            total_ms: v.f64_or("total_ms", 0.0),
            truncated: v.get("truncated").and_then(|b| b.as_bool()).unwrap_or(false),
            rejected: v.get("rejected").and_then(|b| b.as_bool()).unwrap_or(false),
            reason: v.get("reason").and_then(|r| r.as_str()).map(|s| s.to_string()),
            finish_reason: v.str_or("finish_reason", ""),
        }
    }

    /// The terminal shape of a v2 `rejected` frame.
    fn rejected_frame(v: &Value) -> Self {
        GenerateReply {
            id: v.usize_or("id", 0) as u64,
            worker: 0,
            prompt_len: 0,
            tokens: Vec::new(),
            ttft_ms: 0.0,
            total_ms: 0.0,
            truncated: false,
            rejected: true,
            reason: v.get("reason").and_then(|r| r.as_str()).map(|s| s.to_string()),
            finish_reason: "rejected".to_string(),
        }
    }
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    fn read_value(&mut self) -> Result<Value> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection");
        }
        let v = json::parse(line.trim()).map_err(anyhow::Error::msg)?;
        if let Some(err) = v.get("error") {
            bail!("server error: {:?}", err.as_str());
        }
        Ok(v)
    }

    fn send(&mut self, v: &Value) -> Result<()> {
        writeln!(self.stream, "{}", json::write(v))?;
        Ok(())
    }

    // ------------------------------------------------------------- v1

    /// v1 one-shot generation (kept for compatibility; greedy only).
    pub fn generate(
        &mut self,
        prompt: &[u32],
        max_tokens: usize,
        session: Option<u64>,
    ) -> Result<GenerateReply> {
        let mut fields = vec![(
            "prompt",
            Value::Arr(prompt.iter().map(|&t| num(t as f64)).collect()),
        )];
        GenParams::greedy(max_tokens).fields(&mut fields);
        if let Some(s) = session {
            fields.push(("session", num(s as f64)));
        }
        self.send(&obj(fields))?;
        let v = self.read_value()?;
        Ok(GenerateReply::from_value(&v))
    }

    // ------------------------------------------------------------- v2

    /// v2 streaming generation: `on_token` runs for every streamed token
    /// as it arrives; return `false` to cancel mid-stream (the reply then
    /// carries `finish_reason == "cancelled"` and the tokens generated up
    /// to the point the cancel landed).
    pub fn generate_stream(
        &mut self,
        prompt: &[u32],
        params: &GenParams,
        session: Option<u64>,
        on_token: impl FnMut(&TokenEvent) -> bool,
    ) -> Result<GenerateReply> {
        let mut fields = vec![
            ("v", num(2.0)),
            ("stream", Value::Bool(true)),
            ("prompt", Value::Arr(prompt.iter().map(|&t| num(t as f64)).collect())),
        ];
        params.fields(&mut fields);
        if let Some(s) = session {
            fields.push(("session", num(s as f64)));
        }
        self.send(&obj(fields))?;
        self.pump_stream(on_token)
    }

    /// Read one request's v2 frames until the terminal `done`/`rejected`.
    fn pump_stream(
        &mut self,
        mut on_token: impl FnMut(&TokenEvent) -> bool,
    ) -> Result<GenerateReply> {
        let mut cancel_sent = false;
        loop {
            let v = self.read_value()?;
            match v.str_or("event", "").as_str() {
                "admitted" | "prefill" | "cancel" => {} // progress / ack
                "token" => {
                    let ev = TokenEvent {
                        id: v.usize_or("id", 0) as u64,
                        token: v.usize_or("token", 0) as u32,
                        logprob: v.f64_or("logprob", 0.0),
                        index: v.usize_or("index", 0),
                    };
                    if !on_token(&ev) && !cancel_sent {
                        self.send(&obj(vec![("v", num(2.0)), ("cancel", num(ev.id as f64))]))?;
                        cancel_sent = true;
                    }
                }
                "done" => return Ok(GenerateReply::from_value(&v)),
                "rejected" => return Ok(GenerateReply::rejected_frame(&v)),
                other => bail!("unexpected v2 frame '{other}'"),
            }
        }
    }

    /// Ask the server for a fresh session id (v2 `open_session`).
    pub fn open_session(&mut self) -> Result<u64> {
        self.send(&obj(vec![("v", num(2.0)), ("open_session", Value::Bool(true))]))?;
        let v = self.read_value()?;
        if v.str_or("event", "") != "session" {
            bail!("expected a session frame, got {v:?}");
        }
        Ok(v.usize_or("session", 0) as u64)
    }

    /// Submit the next turn of a session (tokens are the turn's NEW
    /// tokens only; the server replays history and reuses the session's
    /// KV chain).  Streams like [`Client::generate_stream`].
    pub fn turn(
        &mut self,
        session: u64,
        tokens: &[u32],
        params: &GenParams,
        on_token: impl FnMut(&TokenEvent) -> bool,
    ) -> Result<GenerateReply> {
        let mut fields = vec![
            ("v", num(2.0)),
            ("stream", Value::Bool(true)),
            ("session", num(session as f64)),
            ("turn", Value::Arr(tokens.iter().map(|&t| num(t as f64)).collect())),
        ];
        params.fields(&mut fields);
        self.send(&obj(fields))?;
        self.pump_stream(on_token)
    }

    /// Close a session: the server frees its engine-side KV chain and
    /// drops its router affinity.
    pub fn close_session(&mut self, session: u64) -> Result<()> {
        self.send(&obj(vec![
            ("v", num(2.0)),
            ("session", num(session as f64)),
            ("close", Value::Bool(true)),
        ]))?;
        let v = self.read_value()?;
        if v.str_or("event", "") != "session_closed" {
            bail!("expected session_closed, got {v:?}");
        }
        Ok(())
    }

    /// Fire a cancel for a request id started on THIS connection.
    /// Fire-and-forget on the wire (no ack frame — it would race the
    /// stream's terminal frame); the cancelled request's own stream
    /// answers with `finish_reason: "cancelled"`.  Unknown or
    /// already-finished ids are silently ignored by the server.
    pub fn cancel(&mut self, id: u64) -> Result<()> {
        self.send(&obj(vec![("v", num(2.0)), ("cancel", num(id as f64))]))
    }

    // ----------------------------------------------------------- admin

    fn admin(&mut self, cmd: &str) -> Result<Value> {
        self.send(&obj(vec![("admin", json::s(cmd))]))?;
        self.read_value()
    }

    /// Fleet counters: per-worker objects under `"workers"` plus summed
    /// totals (`tier_hits`, `prefix_tokens_reused`, `session_turns`, ...)
    /// at the top level.
    pub fn metrics(&mut self) -> Result<Value> {
        self.admin("metrics")
    }

    /// Ask the server to drain, snapshot its tiers, and exit.
    pub fn shutdown(&mut self) -> Result<()> {
        self.admin("shutdown").map(|_| ())
    }

    /// Node liveness probe (`{"admin":"ping"}`): the reply object
    /// carries `role`, `workers`, and `draining` — the front tier's
    /// heartbeat reads it to track backend health.
    pub fn ping(&mut self) -> Result<Value> {
        self.admin("ping")
    }

    /// Mark the node draining (`{"admin":"drain"}`).  Advisory on the
    /// backend: the front tier stops placing NEW sessions here while
    /// in-flight requests finish normally.
    pub fn drain(&mut self) -> Result<Value> {
        self.admin("drain")
    }

    /// Drain the fleet's trace rings (`{"admin":"trace"}`): one JSON
    /// value per event (worker order, seq order within a worker), then
    /// the terminator object carrying `events` / `dropped`.  Draining
    /// consumes — a second call returns only events recorded since.
    pub fn trace(&mut self) -> Result<(Vec<Value>, Value)> {
        self.send(&obj(vec![("admin", json::s("trace"))]))?;
        let mut events = Vec::new();
        loop {
            let v = self.read_value()?;
            if v.get("admin").is_some() {
                return Ok((events, v));
            }
            events.push(v);
        }
    }

    /// The fleet's metrics in Prometheus text exposition format
    /// (`{"admin":"prometheus"}` — the reply's `text` field).
    pub fn prometheus(&mut self) -> Result<String> {
        let v = self.admin("prometheus")?;
        Ok(v.str_or("text", ""))
    }
}
