//! JSON-lines TCP serving front-end.
//!
//! The offline image carries no tokio, so the server is plain threads:
//! one engine per worker thread (each owning its own model + cache), a
//! session-affinity router, one thread per connection, and one forwarder
//! thread per in-flight v2 stream.  Two protocol versions share the
//! framing — a frame with no `"v"` field is v1:
//!
//! ```text
//! # v1 one-shot (unchanged; byte-compatible)
//! -> {"prompt": [1,2,3], "max_tokens": 16, "session": 7}
//! <- {"id": 1, "tokens": [...], "ttft_ms": 1.2, "total_ms": 9.8,
//!     "truncated": false, "rejected": false, "finish_reason": "length"}
//!
//! # v2 streaming generation: one line per engine event ("tenant" is
//! # optional — absent means the shared "default" tenant)
//! -> {"v": 2, "stream": true, "prompt": [1,2,3], "max_tokens": 16,
//!     "temperature": 0.8, "top_k": 40, "top_p": 0.95, "seed": 7,
//!     "stop": [0], "tenant": "paid"}
//! <- {"v": 2, "event": "admitted", "id": 1, "worker": 0}
//! <- {"v": 2, "event": "prefill",  "id": 1, "done": 3, "total": 3}
//! <- {"v": 2, "event": "token",    "id": 1, "token": 42,
//!     "logprob": -1.7, "index": 0}
//! <- {"v": 2, "event": "done",     "id": 1, "tokens": [...],
//!     "finish_reason": "stop|length|cancelled|rejected", ...}
//!
//! # v2 cancel (any time; the stream answers with done/cancelled)
//! -> {"v": 2, "cancel": 1}
//!
//! # v2 sessions: open / turn / close (multi-turn KV reuse)
//! -> {"v": 2, "open_session": true}
//! <- {"v": 2, "event": "session", "session": 4294967296, "ok": true}
//! -> {"v": 2, "session": 4294967296, "turn": [4,5], "stream": true}
//! -> {"v": 2, "session": 4294967296, "close": true}
//! ```
//!
//! See the README's "Wire protocol v2" section for the frame-by-frame
//! spec and the version negotiation / compatibility rules.
//!
//! A request the engine refuses (backpressure, empty prompt, unsupported
//! options, busy session, tenant over its rate limit) still gets a
//! reply: `"rejected": true` plus a `"reason"` string — the
//! [`crate::coordinator::RejectReason`] wire label (`queue_full` |
//! `memory_pressure` | `empty_prompt` | `session_busy` |
//! `unsupported_options` | `tenant_throttled`) — distinguishable from
//! `"truncated"`, which means the request RAN but was cut short.
//!
//! Admin requests share the same JSON-lines framing:
//!
//! ```text
//! -> {"admin": "metrics"}     # per-worker counters + fleet totals
//! -> {"admin": "prometheus"}  # text exposition 0.0.4 in "text"
//! -> {"admin": "trace"}       # drain trace rings: one line per event,
//!                             # then {"admin":"trace","ok":true,...}
//! -> {"admin": "ping"}        # liveness: {"role":"serve","workers":N,
//!                             #            "draining":bool}
//! -> {"admin": "drain"}       # advisory: the front tier stops NEW
//!                             # placements here; in-flight finishes
//! -> {"admin": "shutdown"}    # drain, snapshot tiers, exit the server
//! ```
//!
//! The multi-node fabric (see [`crate::fabric`]) rides the same
//! framing: `ping`/`drain` are the front tier's health and drain
//! protocol, and a `{"peer":"fetch","hash":"<decimal u64>"}` frame asks
//! this node for one prefix-cache record — answered by a
//! `{"peer":"fetch","len":N}` header followed by N raw record bytes
//! (`len: 0` is a miss).  Hashes travel as decimal STRINGS because JSON
//! numbers are f64 and round above 2^53.
//!
//! `trace` and `prometheus` are part of the observability layer (see the
//! README's "Observability" section): tracing is off unless the server
//! ran with `--trace on`, in which case each worker's engine records
//! request-lifecycle events into a bounded ring that these commands
//! drain/render.  Every v2 frame echoes the request `id`, which is the
//! join key against the trace events.
//!
//! `shutdown` is how the tiered page store's prefix-cache snapshot gets
//! written: each worker finishes its in-flight requests, persists its
//! tier (when `--tier-dir`/`--snapshot on` are set), and exits; the
//! `serve` process then returns.  A SIGKILL instead of admin shutdown
//! skips the snapshot — the next boot simply starts cold.

pub mod client;
pub mod worker;

pub use client::{Client, GenParams, GenerateReply, TokenEvent};
pub use worker::{serve, serve_with_export, EngineFactory, ServerHandle};
