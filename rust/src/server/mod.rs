//! JSON-lines TCP serving front-end.
//!
//! The offline image carries no tokio, so the server is plain threads:
//! one engine per worker thread (each owning its own model + cache), a
//! session-affinity router, and one thread per connection.  Protocol:
//!
//! ```text
//! -> {"prompt": [1,2,3], "max_tokens": 16, "session": 7}
//! <- {"id": 0, "tokens": [...], "ttft_ms": 1.2, "total_ms": 9.8}
//! ```

pub mod client;
pub mod worker;

pub use client::Client;
pub use worker::{serve, EngineFactory, ServerHandle};
