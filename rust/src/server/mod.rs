//! JSON-lines TCP serving front-end.
//!
//! The offline image carries no tokio, so the server is plain threads:
//! one engine per worker thread (each owning its own model + cache), a
//! session-affinity router, and one thread per connection.  Protocol:
//!
//! ```text
//! -> {"prompt": [1,2,3], "max_tokens": 16, "session": 7}
//! <- {"id": 0, "tokens": [...], "ttft_ms": 1.2, "total_ms": 9.8,
//!     "truncated": false, "rejected": false}
//! ```
//!
//! A request the engine refuses (backpressure, empty prompt) still gets a
//! reply: `"rejected": true` plus a `"reason"` string
//! (`queue_full` | `memory_pressure` | `empty_prompt`) — distinguishable
//! from `"truncated"`, which means the request RAN but was cut short.
//!
//! Admin requests share the same JSON-lines framing:
//!
//! ```text
//! -> {"admin": "metrics"}    # per-worker counters + fleet totals
//! -> {"admin": "shutdown"}   # drain, snapshot tiers, exit the server
//! ```
//!
//! `shutdown` is how the tiered page store's prefix-cache snapshot gets
//! written: each worker finishes its in-flight requests, persists its
//! tier (when `--tier-dir`/`--snapshot on` are set), and exits; the
//! `serve` process then returns.  A SIGKILL instead of admin shutdown
//! skips the snapshot — the next boot simply starts cold.

pub mod client;
pub mod worker;

pub use client::Client;
pub use worker::{serve, EngineFactory, ServerHandle};
