//! Engine worker threads + the TCP accept loop.
//!
//! Two layers of parallelism compose here: `n_workers` engines (each with
//! its own model + cache, fed by the session-affinity router), and inside
//! each native engine an optional decode pool (`EngineOpts::decode_workers`)
//! that fans every decode iteration over balanced cache-length shards.
//! The factory decides the per-engine pool width; `serve` just reports it.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::router::Router;
use crate::coordinator::{Completion, Engine, Request};
use crate::util::json::{self, num, obj, Value};

/// Builds one engine per worker (engines are not Send-shareable across
/// workers by design — each owns its model and cache).
pub type EngineFactory = Arc<dyn Fn(usize) -> Engine + Send + Sync>;

struct Job {
    req: Request,
    reply: Sender<Completion>,
}

/// Submit a job to the engine; a rejected request gets an explicit
/// `rejected` reply with the `AdmitDecision` reason instead of a silently
/// dropped `Sender` (which left `handle_conn` waiting on a channel that
/// could never deliver).  EVERY path that submits must go through here.
fn submit_job(engine: &mut Engine, job: Job, replies: &mut HashMap<u64, Sender<Completion>>) {
    let id = job.req.id;
    let prompt_len = job.req.prompt.len();
    match engine.submit(job.req) {
        Ok(()) => {
            replies.insert(id, job.reply);
        }
        Err(why) => {
            let _ = job.reply.send(Completion::rejected(id, prompt_len, why));
        }
    }
}

fn worker_loop(engine: &mut Engine, rx: Receiver<Job>, shutdown: &AtomicBool) {
    let mut replies: HashMap<u64, Sender<Completion>> = HashMap::new();
    loop {
        // drain new jobs; block briefly when idle
        loop {
            match rx.try_recv() {
                Ok(job) => submit_job(engine, job, &mut replies),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if engine.idle() {
                        return;
                    }
                    break;
                }
            }
        }
        if engine.idle() {
            if shutdown.load(Ordering::Relaxed) {
                return;
            }
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(job) => submit_job(engine, job, &mut replies),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
        match engine.step() {
            Ok(completions) => {
                for c in completions {
                    if let Some(tx) = replies.remove(&c.id) {
                        let _ = tx.send(c);
                    }
                }
            }
            Err(e) => {
                eprintln!("engine step error: {e:#}");
                return;
            }
        }
    }
}

/// A running server: listener thread + engine workers.
pub struct ServerHandle {
    pub addr: String,
    workers: Vec<JoinHandle<()>>,
    listener_thread: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Signal shutdown and join all threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // poke the listener so accept() returns
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Start a server on `addr` ("127.0.0.1:0" for an ephemeral port) with
/// `n_workers` engines.  Returns once the listener is bound.
pub fn serve(factory: EngineFactory, addr: &str, n_workers: usize) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?.to_string();
    let shutdown = Arc::new(AtomicBool::new(false));

    let mut senders = Vec::new();
    let mut workers = Vec::new();
    for w in 0..n_workers {
        let (tx, rx) = channel::<Job>();
        senders.push(tx);
        let factory = factory.clone();
        let sd = shutdown.clone();
        workers.push(std::thread::spawn(move || {
            let mut engine = factory(w);
            if engine.decode_pool_width() > 1 {
                eprintln!(
                    "[server] engine {w}: decode pool width {}",
                    engine.decode_pool_width()
                );
            }
            if engine.prefill_chunk_size() > 0 {
                eprintln!(
                    "[server] engine {w}: chunked prefill, {} tokens/step",
                    engine.prefill_chunk_size()
                );
            }
            if engine.cache_pages() > 0 {
                eprintln!(
                    "[server] engine {w}: page pool capped at {} group-pages \
                     (preemptive eviction on exhaustion)",
                    engine.cache_pages()
                );
            }
            if engine.prefix_caching() {
                eprintln!("[server] engine {w}: prefix caching ON (refcounted page sharing)");
            }
            worker_loop(&mut engine, rx, &sd)
        }));
    }
    let router = Arc::new(Mutex::new(Router::new(n_workers)));
    let next_id = Arc::new(Mutex::new(0u64));

    let sd = shutdown.clone();
    let listener_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if sd.load(Ordering::Relaxed) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let senders = senders.clone();
            let router = router.clone();
            let next_id = next_id.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, &senders, &router, &next_id);
            });
        }
    });

    Ok(ServerHandle {
        addr: local,
        workers,
        listener_thread: Some(listener_thread),
        shutdown,
    })
}

fn handle_conn(
    stream: TcpStream,
    senders: &[Sender<Job>],
    router: &Arc<Mutex<Router>>,
    next_id: &Arc<Mutex<u64>>,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                writeln!(stream, "{}", json::write(&obj(vec![("error", json::s(&e.0))])))?;
                continue;
            }
        };
        let prompt: Vec<u32> = v
            .get("prompt")
            .and_then(|p| p.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).map(|x| x as u32).collect())
            .unwrap_or_default();
        let max_tokens = v.usize_or("max_tokens", 16);
        let session = v.get("session").and_then(|s| s.as_i64()).map(|s| s as u64);

        let id = {
            let mut n = next_id.lock().unwrap();
            *n += 1;
            *n
        };
        let worker = router.lock().unwrap().route(session);
        let mut req = Request::greedy(id, prompt, max_tokens);
        req.session = session;
        let (tx, rx) = channel();
        senders[worker]
            .send(Job { req, reply: tx })
            .map_err(|_| anyhow::anyhow!("worker {} gone", worker))?;
        let completion = rx.recv().context("worker dropped reply")?;
        router.lock().unwrap().complete(worker);

        let tokens = Value::Arr(
            completion.tokens.iter().map(|&t| num(t as f64)).collect(),
        );
        let mut fields = vec![
            ("id", num(id as f64)),
            ("worker", num(worker as f64)),
            ("prompt_len", num(completion.prompt_len as f64)),
            ("tokens", tokens),
            ("ttft_ms", num(completion.ttft_s.unwrap_or(0.0) * 1e3)),
            ("total_ms", num(completion.total_s.unwrap_or(0.0) * 1e3)),
            ("truncated", Value::Bool(completion.truncated)),
            ("rejected", Value::Bool(completion.rejected)),
        ];
        if let Some(reason) = completion.reason {
            fields.push(("reason", json::s(reason)));
        }
        let reply = obj(fields);
        writeln!(stream, "{}", json::write(&reply))?;
    }
}
