//! Engine worker threads + the TCP accept loop.
//!
//! Two layers of parallelism compose here: `n_workers` engines (each with
//! its own model + cache, fed by the session-affinity router), and inside
//! each native engine an optional decode pool (`EngineOpts::decode_workers`)
//! that fans every decode iteration over balanced cache-length shards.
//! The factory decides the per-engine pool width; `serve` just reports it.
//!
//! Two wire protocols share the JSON-lines framing (see the module docs
//! in [`super`] and the README's "Wire protocol v2" section):
//!
//! * **v1** (no `"v"` field): one-shot request -> one reply line.  Kept
//!   byte-compatible; the engine runs the identical greedy computation.
//! * **v2** (`"v": 2`): streaming generation (one line per engine
//!   [`Event`]), mid-stream `{"cancel": id}`, and session open / turn /
//!   close frames for multi-turn KV reuse.  Each streaming request gets a
//!   forwarder thread pumping engine events to the (line-locked) socket,
//!   so the connection loop keeps reading — that is what makes
//!   cancellation reachable mid-stream.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::router::Router;
use crate::coordinator::{Completion, Engine, Event, GenOptions, Request, RequestId, SchedMode};
use crate::util::json::{self, num, obj, Value};

/// Builds one engine per worker (engines are not Send-shareable across
/// workers by design — each owns its model and cache).
pub type EngineFactory = Arc<dyn Fn(usize) -> Engine + Send + Sync>;

enum Job {
    /// v1 one-shot: reply with the final completion only.
    Run { req: Request, reply: Sender<Completion> },
    /// v2: the engine streams events straight into `events`.
    Stream { req: Request, events: Sender<Event> },
    /// v2 session turn (`req.prompt` = the turn's NEW tokens only).
    Turn { sid: u64, req: Request, events: Sender<Event> },
    /// v2 cancel; the in-flight request's stream gets Done(cancelled).
    Cancel { id: RequestId },
    /// v2 session close: frees the engine-side chain.
    EndSession { sid: u64 },
    /// Admin introspection: the worker answers with its counters
    /// immediately, even mid-batch.
    Metrics { reply: Sender<Value> },
}

/// Submit a job to the engine; a rejected request gets an explicit
/// `rejected` reply with the `AdmitDecision` reason instead of a silently
/// dropped `Sender` (which left `handle_conn` waiting on a channel that
/// could never deliver).  EVERY path that submits must go through here.
fn submit_job(engine: &mut Engine, job: Job, replies: &mut HashMap<u64, Sender<Completion>>) {
    match job {
        Job::Run { req, reply } => {
            let id = req.id;
            let prompt_len = req.prompt.len();
            match engine.submit(req) {
                Ok(()) => {
                    replies.insert(id, reply);
                }
                Err(why) => {
                    let _ = reply.send(Completion::rejected(id, prompt_len, why));
                }
            }
        }
        // the engine owns event delivery (incl. the Rejected event), so
        // nothing to track here
        Job::Stream { req, events } => {
            let _ = engine.submit_with_events(req, events);
        }
        Job::Turn { sid, req, events } => {
            let _ = engine.submit_turn(sid, req, events);
        }
        Job::Cancel { id } => {
            engine.cancel(id);
        }
        Job::EndSession { sid } => {
            engine.end_session(sid);
        }
        Job::Metrics { reply } => {
            let _ = reply.send(metrics_value(engine));
        }
    }
}

/// One worker's counters as a JSON object.  Tier values come straight
/// from the pool (not the per-step metric gauges) so an admin query after
/// the last step still sees the final promotion/demotion counts.
fn metrics_value(engine: &Engine) -> Value {
    let m = &engine.metrics;
    let pool = engine.page_pool();
    // percentiles are NaN before the first sample; 0 keeps the reply
    // valid JSON (our writer would emit a bare NaN otherwise)
    let ms = |secs: f64| num(if secs.is_finite() { secs * 1e3 } else { 0.0 });
    obj(vec![
        ("requests_submitted", num(m.requests_submitted as f64)),
        ("requests_finished", num(m.requests_finished as f64)),
        ("requests_rejected", num(m.requests_rejected as f64)),
        ("requests_cancelled", num(m.requests_cancelled as f64)),
        ("session_turns", num(m.session_turns as f64)),
        ("session_tokens_reused", num(m.session_tokens_reused as f64)),
        ("prefill_tokens", num(m.prefill_tokens as f64)),
        ("decode_tokens", num(m.decode_tokens as f64)),
        ("prefix_hits", num(m.prefix_hits as f64)),
        ("prefix_tokens_reused", num(m.prefix_tokens_reused as f64)),
        ("preemptions", num(m.preemptions as f64)),
        ("pages_in_use", num(pool.pages_in_use() as f64)),
        ("pages_evicted", num(pool.pages_evicted() as f64)),
        ("tier_hits", num(pool.tier_hits() as f64)),
        ("pages_demoted", num(pool.pages_demoted() as f64)),
        ("pages_promoted", num(pool.pages_promoted() as f64)),
        ("bytes_on_disk", num(pool.bytes_on_disk() as f64)),
        ("tier_session_bytes", num(pool.session_bytes() as f64)),
        ("snapkv_tokens_dropped", num(m.snapkv_tokens_dropped as f64)),
        ("tenant_throttled", num(m.tenant_throttled as f64)),
        ("sessions_reaped", num(m.sessions_reaped as f64)),
        ("sessions_restored", num(m.sessions_restored as f64)),
        ("speculative_rounds", num(m.speculative_rounds as f64)),
        ("speculative_drafted", num(m.speculative_drafted as f64)),
        ("speculative_accepted", num(m.speculative_accepted as f64)),
        // per-request latency histograms (p50/p95/p99, milliseconds)
        ("ttft_ms_p50", ms(m.ttft.p(50.0))),
        ("ttft_ms_p95", ms(m.ttft.p(95.0))),
        ("ttft_ms_p99", ms(m.ttft.p(99.0))),
        ("itl_ms_p50", ms(m.itl.p(50.0))),
        ("itl_ms_p95", ms(m.itl.p(95.0))),
        ("itl_ms_p99", ms(m.itl.p(99.0))),
        // the QK score kernel actually running ("scalar" / "simd" /
        // "pjrt-graph") — non-numeric, so the client's cross-worker
        // aggregation skips it
        ("kernel", json::s(engine.kernel_name())),
        // per-tenant breakdown keyed by tenant name (non-numeric object,
        // so the client's cross-worker aggregation skips it)
        ("tenants", tenants_value(m)),
        ("summary", json::s(&m.summary())),
    ])
}

/// The per-tenant counters as `{name: {...}}`.  Tenant names are dynamic
/// keys, so the object is built directly instead of through `obj`.
fn tenants_value(m: &crate::coordinator::metrics::Metrics) -> Value {
    let ms = |secs: f64| num(if secs.is_finite() { secs * 1e3 } else { 0.0 });
    let mut map = std::collections::BTreeMap::new();
    for (name, t) in &m.tenants {
        map.insert(
            name.clone(),
            obj(vec![
                ("admitted", num(t.admitted as f64)),
                ("throttled", num(t.throttled as f64)),
                ("finished", num(t.finished as f64)),
                ("decode_tokens", num(t.decode_tokens as f64)),
                ("itl_ms_p50", ms(t.itl.p(50.0))),
                ("itl_ms_p99", ms(t.itl.p(99.0))),
            ]),
        );
    }
    Value::Obj(map)
}

fn worker_loop(engine: &mut Engine, rx: Receiver<Job>, shutdown: &AtomicBool) {
    let mut replies: HashMap<u64, Sender<Completion>> = HashMap::new();
    loop {
        // drain new jobs; block briefly when idle
        loop {
            match rx.try_recv() {
                Ok(job) => submit_job(engine, job, &mut replies),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if engine.idle() {
                        return;
                    }
                    break;
                }
            }
        }
        if engine.idle() {
            if shutdown.load(Ordering::Relaxed) {
                return;
            }
            // step() reaps while the engine is busy; an idle worker spins
            // here without stepping, so the TTL sweep must run explicitly
            // or sessions idling on an otherwise-quiet worker never reap
            engine.reap_idle_sessions();
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(job) => submit_job(engine, job, &mut replies),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
        match engine.step() {
            Ok(completions) => {
                for c in completions {
                    if let Some(tx) = replies.remove(&c.id) {
                        let _ = tx.send(c);
                    }
                }
            }
            Err(e) => {
                eprintln!("engine step error: {e:#}");
                return;
            }
        }
    }
}

/// A running server: listener thread + engine workers.
pub struct ServerHandle {
    pub addr: String,
    workers: Vec<JoinHandle<()>>,
    listener_thread: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Signal shutdown and join all threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // poke the listener so accept() returns
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Block until the server shuts down on its own — i.e. until a
    /// client sends `{"admin": "shutdown"}` and every worker drains,
    /// snapshots its tier, and exits.  The `serve` subcommand parks on
    /// this instead of sleeping forever, so graceful shutdown (and the
    /// tier snapshot it triggers) is reachable over the wire.
    pub fn wait(mut self) {
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.shutdown.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
    }
}

/// Start a server on `addr` ("127.0.0.1:0" for an ephemeral port) with
/// `n_workers` engines.  Returns once the listener is bound.
pub fn serve(factory: EngineFactory, addr: &str, n_workers: usize) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?.to_string();
    let shutdown = Arc::new(AtomicBool::new(false));

    let mut senders = Vec::new();
    let mut workers = Vec::new();
    for w in 0..n_workers {
        let (tx, rx) = channel::<Job>();
        senders.push(tx);
        let factory = factory.clone();
        let sd = shutdown.clone();
        workers.push(std::thread::spawn(move || {
            let mut engine = factory(w);
            eprintln!("[server] engine {w}: QK score kernel '{}'", engine.kernel_name());
            if engine.decode_pool_width() > 1 {
                eprintln!(
                    "[server] engine {w}: decode pool width {}",
                    engine.decode_pool_width()
                );
            }
            if engine.prefill_chunk_size() > 0 {
                eprintln!(
                    "[server] engine {w}: chunked prefill, {} tokens/step",
                    engine.prefill_chunk_size()
                );
            }
            if engine.cache_pages() > 0 {
                eprintln!(
                    "[server] engine {w}: page pool capped at {} group-pages \
                     (preemptive eviction on exhaustion)",
                    engine.cache_pages()
                );
            }
            if engine.prefix_caching() {
                eprintln!("[server] engine {w}: prefix caching ON (refcounted page sharing)");
            }
            if engine.speculate_k() > 0 {
                let bits = engine
                    .draft_spec()
                    .map(|d| format!("r{}/t{}", d.r_bits, d.t_bits))
                    .unwrap_or_else(|| "unset".into());
                eprintln!(
                    "[server] engine {w}: speculative decoding, K={} on draft plane {bits} \
                     (greedy requests only; output stays bit-identical)",
                    engine.speculate_k()
                );
            }
            if engine.sched_mode() == SchedMode::Wfq {
                eprintln!(
                    "[server] engine {w}: weighted-fair tenant scheduling (deficit stride)"
                );
            }
            if let Some(ttl) = engine.session_ttl() {
                eprintln!(
                    "[server] engine {w}: idle-session TTL {:.1}s (reap to disk tier)",
                    ttl.as_secs_f64()
                );
            }
            if let Some(t) = engine.tier() {
                eprintln!(
                    "[server] engine {w}: tiered page store at {} ({} prefix entries \
                     restored, {} bytes on disk, snapshot {})",
                    t.dir.display(),
                    engine.tier_restored(),
                    engine.page_pool().bytes_on_disk(),
                    if t.snapshot { "on" } else { "off" },
                );
            }
            worker_loop(&mut engine, rx, &sd);
            // graceful exit: persist the prefix cache for the next boot
            match engine.snapshot_tier() {
                Ok(Some((entries, bytes))) => eprintln!(
                    "[server] engine {w}: tier snapshot written ({entries} prefix entries, \
                     {bytes} bytes on disk)"
                ),
                Ok(None) => {}
                Err(e) => eprintln!("[server] engine {w}: tier snapshot failed: {e:#}"),
            }
        }));
    }
    let router = Arc::new(Mutex::new(Router::new(n_workers)));
    let next_id = Arc::new(AtomicU64::new(0));
    // server-allocated session ids start high so they never collide with
    // client-chosen v1 affinity keys in the router's sticky map
    let next_session = Arc::new(AtomicU64::new(1 << 32));

    let sd = shutdown.clone();
    let listener_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if sd.load(Ordering::Relaxed) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let senders = senders.clone();
            let router = router.clone();
            let next_id = next_id.clone();
            let next_session = next_session.clone();
            let sd = sd.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, &senders, &router, &next_id, &next_session, &sd);
            });
        }
    });

    Ok(ServerHandle {
        addr: local,
        workers,
        listener_thread: Some(listener_thread),
        shutdown,
    })
}

/// Answer an `{"admin": ...}` request.  `metrics` fans out to every
/// worker and returns both the per-worker objects and fleet totals for
/// the counters monitoring cares about; `shutdown` flips the flag that
/// makes each worker exit (and snapshot its tier) once idle.
fn handle_admin(cmd: &str, senders: &[Sender<Job>], shutdown: &AtomicBool) -> Value {
    match cmd {
        "shutdown" => {
            shutdown.store(true, Ordering::Relaxed);
            obj(vec![("admin", json::s("shutdown")), ("ok", Value::Bool(true))])
        }
        "metrics" => {
            let mut per_worker = Vec::new();
            for s in senders {
                let (tx, rx) = channel();
                if s.send(Job::Metrics { reply: tx }).is_ok() {
                    if let Ok(v) = rx.recv_timeout(Duration::from_secs(10)) {
                        per_worker.push(v);
                    }
                }
            }
            const TOTALS: &[&str] = &[
                "requests_finished",
                "requests_rejected",
                "requests_cancelled",
                "session_turns",
                "session_tokens_reused",
                "prefill_tokens",
                "decode_tokens",
                "prefix_hits",
                "prefix_tokens_reused",
                "preemptions",
                "pages_in_use",
                "pages_evicted",
                "tier_hits",
                "pages_demoted",
                "pages_promoted",
                "bytes_on_disk",
                "tier_session_bytes",
                "snapkv_tokens_dropped",
                "tenant_throttled",
                "sessions_reaped",
                "sessions_restored",
                "speculative_rounds",
                "speculative_drafted",
                "speculative_accepted",
            ];
            let mut fields: Vec<(&str, Value)> =
                vec![("admin", json::s("metrics")), ("ok", Value::Bool(true))];
            for &key in TOTALS {
                let total: f64 = per_worker
                    .iter()
                    .map(|w| w.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0))
                    .sum();
                fields.push((key, num(total)));
            }
            fields.push(("workers", Value::Arr(per_worker)));
            obj(fields)
        }
        other => obj(vec![
            ("ok", Value::Bool(false)),
            ("error", json::s(&format!("unknown admin command '{other}'"))),
        ]),
    }
}

/// Line-atomic shared writer: streaming forwarder threads and the
/// connection loop interleave whole frames, never partial lines.
type SharedStream = Arc<Mutex<TcpStream>>;

fn write_line(out: &SharedStream, v: &Value) -> std::io::Result<()> {
    let mut s = out.lock().unwrap();
    writeln!(s, "{}", json::write(v))
}

fn error_frame(msg: &str) -> Value {
    obj(vec![("error", json::s(msg))])
}

/// Token-id array field (`"prompt"` / `"turn"` / `"stop"`).
fn tokens_field(v: &Value, key: &str) -> Option<Vec<u32>> {
    v.get(key)
        .and_then(|p| p.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_usize()).map(|x| x as u32).collect())
}

/// `"seed"` accepts a decimal string (full u64 range) or a JSON number.
/// Numbers ride an f64 and round above 2^53 — silently running a
/// DIFFERENT seed than the client asked for would break the
/// bit-identical-rollout contract, so anything ambiguous is an error,
/// not a guess (matching the strict-parser convention elsewhere).
fn seed_field(v: &Value) -> Result<u64, String> {
    const F64_EXACT: f64 = (1u64 << 53) as f64;
    match v.get("seed") {
        None => Ok(0),
        Some(Value::Str(s)) => {
            s.parse().map_err(|_| format!("seed '{s}' is not a decimal u64"))
        }
        Some(n) => match n.as_f64() {
            Some(f) if f >= 0.0 && f.fract() == 0.0 && f <= F64_EXACT => Ok(f as u64),
            _ => Err(
                "seed must be a non-negative integer <= 2^53; send a decimal STRING \
                 for the full u64 range"
                    .to_string(),
            ),
        },
    }
}

/// Per-request generation options from a v1/v2 request frame; every
/// field defaults to the greedy v1 behavior.  `Err` carries a message
/// for the wire's error frame.
fn gen_options(v: &Value) -> Result<GenOptions, String> {
    Ok(GenOptions {
        max_new_tokens: v.usize_or("max_tokens", 16),
        temperature: v.f64_or("temperature", 0.0) as f32,
        top_k: v.usize_or("top_k", 0),
        top_p: v.f64_or("top_p", 1.0) as f32,
        seed: seed_field(v)?,
        stop_tokens: tokens_field(v, "stop").unwrap_or_default(),
        // logprobs cost two O(vocab) passes per token: only streamed
        // frames (or an explicit "logprobs": true) pay for them — the
        // one-shot reply carries no logprobs anyway
        logprobs: v.get("logprobs").and_then(|b| b.as_bool()).unwrap_or(false),
        snapkv: match v.usize_or("snapkv_budget", 0) {
            0 => None,
            budget => Some(crate::coordinator::SnapKvOpts {
                budget,
                window: v.usize_or("snapkv_window", 8),
            }),
        },
    })
}

/// The completion fields shared by the v1 reply and the v2 `done` frame.
fn completion_fields(c: &Completion, worker: usize) -> Vec<(&'static str, Value)> {
    let tokens = Value::Arr(c.tokens.iter().map(|&t| num(t as f64)).collect());
    let mut fields = vec![
        ("id", num(c.id as f64)),
        ("worker", num(worker as f64)),
        ("prompt_len", num(c.prompt_len as f64)),
        ("tokens", tokens),
        ("ttft_ms", num(c.ttft_s.unwrap_or(0.0) * 1e3)),
        ("total_ms", num(c.total_s.unwrap_or(0.0) * 1e3)),
        ("truncated", Value::Bool(c.truncated)),
        ("rejected", Value::Bool(c.rejected)),
        ("finish_reason", json::s(c.finish_reason.as_str())),
    ];
    if let Some(reason) = c.reason {
        fields.push(("reason", json::s(reason.as_str())));
    }
    fields
}

/// One engine [`Event`] as a v2 frame.
fn event_frame(ev: &Event, worker: usize) -> Value {
    let base = |event: &str| vec![("v", num(2.0)), ("event", json::s(event))];
    match ev {
        Event::Admitted { id } => {
            let mut f = base("admitted");
            f.push(("id", num(*id as f64)));
            f.push(("worker", num(worker as f64)));
            obj(f)
        }
        Event::PrefillProgress { id, done, total } => {
            let mut f = base("prefill");
            f.push(("id", num(*id as f64)));
            f.push(("done", num(*done as f64)));
            f.push(("total", num(*total as f64)));
            obj(f)
        }
        Event::Token { id, token, logprob, index } => {
            let mut f = base("token");
            f.push(("id", num(*id as f64)));
            f.push(("token", num(*token as f64)));
            f.push(("logprob", num(*logprob as f64)));
            f.push(("index", num(*index as f64)));
            obj(f)
        }
        Event::Done(c) => {
            let mut f = base("done");
            f.extend(completion_fields(c, worker));
            obj(f)
        }
        Event::Rejected { id, reason } => {
            let mut f = base("rejected");
            f.push(("id", num(*id as f64)));
            f.push(("reason", json::s(reason.as_str())));
            obj(f)
        }
    }
}

/// The connection's live request registry (id -> worker), shared with
/// the stream forwarders so finished requests stop being cancellable and
/// the map cannot grow without bound on a long-lived connection.
type ConnRequests = Arc<Mutex<HashMap<u64, usize>>>;

/// Pump one request's engine events to the socket until the terminal
/// frame (`done` / `rejected`), a dead socket, or a dead worker — then
/// decrement the router load EXACTLY ONCE and drop the id from the
/// connection's registry.  With `stream` off only the terminal frame is
/// written (the v2 non-streaming shape).
fn pump_events(
    id: u64,
    rx: Receiver<Event>,
    out: SharedStream,
    router: Arc<Mutex<Router>>,
    requests: ConnRequests,
    worker: usize,
    stream: bool,
) {
    let mut terminated = false;
    while let Ok(ev) = rx.recv() {
        let terminal = matches!(ev, Event::Done(_) | Event::Rejected { .. });
        // a failed write = client went away mid-stream: stop forwarding;
        // the engine finishes the request and its events fall on the floor
        if (stream || terminal) && write_line(&out, &event_frame(&ev, worker)).is_err() {
            terminated = true; // nobody is reading; don't write more
            break;
        }
        if terminal {
            terminated = true;
            break;
        }
    }
    if !terminated {
        // the worker died (engine step error) before finishing this
        // request: tell the client instead of leaving it blocked on read
        let _ = write_line(&out, &error_frame("worker terminated before the request finished"));
    }
    requests.lock().unwrap().remove(&id);
    router.lock().unwrap().complete(worker);
}

fn handle_conn(
    stream: TcpStream,
    senders: &[Sender<Job>],
    router: &Arc<Mutex<Router>>,
    next_id: &Arc<AtomicU64>,
    next_session: &Arc<AtomicU64>,
    shutdown: &AtomicBool,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let out: SharedStream = Arc::new(Mutex::new(stream));
    // live requests started on THIS connection: id -> worker (cancel
    // routing); forwarders prune their id at the terminal frame
    let my_requests: ConnRequests = Arc::new(Mutex::new(HashMap::new()));
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                write_line(&out, &error_frame(&e.0))?;
                continue;
            }
        };
        if let Some(cmd) = v.get("admin").and_then(|a| a.as_str()) {
            let reply = handle_admin(cmd, senders, shutdown);
            write_line(&out, &reply)?;
            continue;
        }
        match v.usize_or("v", 1) {
            1 => handle_v1(&v, &out, senders, router, next_id)?,
            2 => handle_v2(&v, &out, senders, router, next_id, next_session, &my_requests)?,
            other => write_line(&out, &error_frame(&format!(
                "unsupported protocol version {other} (this server speaks v1 and v2)"
            )))?,
        }
    }
}

/// The v1 one-shot path, byte-compatible with the pre-streaming protocol
/// (plus the additive `finish_reason` field).
fn handle_v1(
    v: &Value,
    out: &SharedStream,
    senders: &[Sender<Job>],
    router: &Arc<Mutex<Router>>,
    next_id: &Arc<AtomicU64>,
) -> Result<()> {
    let prompt = tokens_field(v, "prompt").unwrap_or_default();
    let session = v.get("session").and_then(|s| s.as_i64()).map(|s| s as u64);
    let gen = match gen_options(v) {
        Ok(g) => g,
        Err(e) => {
            write_line(out, &error_frame(&e))?;
            return Ok(());
        }
    };

    let id = next_id.fetch_add(1, Ordering::Relaxed) + 1;
    let worker = router.lock().unwrap().route(session);
    let mut req = Request::new(id, prompt, gen);
    req.session = session;
    let (tx, rx) = channel();
    // complete() must run exactly once per route() even when the worker
    // is gone — collect the result first, decrement, then propagate
    let completion = senders[worker]
        .send(Job::Run { req, reply: tx })
        .map_err(|_| anyhow::anyhow!("worker {} gone", worker))
        .and_then(|()| rx.recv().context("worker dropped reply"));
    router.lock().unwrap().complete(worker);
    let completion = completion?;
    write_line(out, &obj(completion_fields(&completion, worker)))?;
    Ok(())
}

/// v2 frames: `open_session` / `close` / `cancel` control frames answer
/// inline; `prompt` / `turn` submissions stream through `pump_events`.
#[allow(clippy::too_many_arguments)]
fn handle_v2(
    v: &Value,
    out: &SharedStream,
    senders: &[Sender<Job>],
    router: &Arc<Mutex<Router>>,
    next_id: &Arc<AtomicU64>,
    next_session: &Arc<AtomicU64>,
    my_requests: &ConnRequests,
) -> Result<()> {
    // -- session open ---------------------------------------------------
    if v.get("open_session").and_then(|b| b.as_bool()).unwrap_or(false) {
        let sid = next_session.fetch_add(1, Ordering::Relaxed);
        write_line(out, &obj(vec![
            ("v", num(2.0)),
            ("event", json::s("session")),
            ("session", num(sid as f64)),
            ("ok", Value::Bool(true)),
        ]))?;
        return Ok(());
    }
    // -- cancel ---------------------------------------------------------
    if let Some(id) = v.get("cancel").and_then(|c| c.as_usize()) {
        let id = id as u64;
        // fire-and-forget BY DESIGN: an inline ack frame would race the
        // request's own forwarder for the stream mutex (an ack landing
        // after `done` desyncs every later reply on the connection).
        // The observable answer is the cancelled request's terminal
        // frame; unknown/already-finished ids are silently ignored.
        if let Some(&worker) = my_requests.lock().unwrap().get(&id) {
            let _ = senders[worker].send(Job::Cancel { id });
        }
        return Ok(());
    }
    let session = v.get("session").and_then(|s| s.as_i64()).map(|s| s as u64);
    // -- session close --------------------------------------------------
    if v.get("close").and_then(|b| b.as_bool()).unwrap_or(false) {
        let Some(sid) = session else {
            write_line(out, &error_frame("close needs a session id"))?;
            return Ok(());
        };
        // idempotent: a session with no routed turn has no engine-side
        // state to free, so there is nothing to address
        let worker = router.lock().unwrap().session_worker(sid);
        if let Some(w) = worker {
            let _ = senders[w].send(Job::EndSession { sid });
        }
        router.lock().unwrap().end_session(sid);
        write_line(out, &obj(vec![
            ("v", num(2.0)),
            ("event", json::s("session_closed")),
            ("session", num(sid as f64)),
            ("ok", Value::Bool(true)),
        ]))?;
        return Ok(());
    }
    // -- generate / turn ------------------------------------------------
    let turn = tokens_field(v, "turn");
    let prompt = tokens_field(v, "prompt");
    if turn.is_some() && session.is_none() {
        write_line(out, &error_frame("turn needs a session id"))?;
        return Ok(());
    }
    if turn.is_none() && prompt.is_none() {
        write_line(out, &error_frame(
            "expected one of prompt, turn, cancel, open_session, close",
        ))?;
        return Ok(());
    }
    let mut gen = match gen_options(v) {
        Ok(g) => g,
        Err(e) => {
            write_line(out, &error_frame(&e))?;
            return Ok(());
        }
    };
    let stream = v.get("stream").and_then(|b| b.as_bool()).unwrap_or(false);
    gen.logprobs |= stream;
    // optional tenant identity; absent / empty -> the default tenant
    // (`Request::new` already carries it), so v1-shaped traffic and plain
    // v2 clients need no change
    let tenant = v.get("tenant").and_then(|t| t.as_str()).unwrap_or("");
    let id = next_id.fetch_add(1, Ordering::Relaxed) + 1;
    let worker = router.lock().unwrap().route(session);
    my_requests.lock().unwrap().insert(id, worker);
    let (tx, rx) = channel::<Event>();
    let job = match turn {
        Some(tokens) => {
            let mut req = Request::new(id, tokens, gen);
            req.session = session;
            if !tenant.is_empty() {
                req.tenant = tenant.to_string();
            }
            Job::Turn { sid: session.expect("checked above"), req, events: tx }
        }
        None => {
            let mut req = Request::new(id, prompt.expect("checked above"), gen);
            req.session = session;
            if !tenant.is_empty() {
                req.tenant = tenant.to_string();
            }
            Job::Stream { req, events: tx }
        }
    };
    if senders[worker].send(job).is_err() {
        my_requests.lock().unwrap().remove(&id);
        router.lock().unwrap().complete(worker);
        write_line(out, &error_frame(&format!("worker {worker} gone")))?;
        return Ok(());
    }
    if stream {
        // forwarder thread: the connection loop keeps reading, so a
        // {"cancel": id} frame can land mid-stream
        let out = out.clone();
        let router = router.clone();
        let requests = my_requests.clone();
        std::thread::spawn(move || pump_events(id, rx, out, router, requests, worker, true));
    } else {
        // one-shot v2: block until the terminal frame
        pump_events(id, rx, out.clone(), router.clone(), my_requests.clone(), worker, false);
    }
    Ok(())
}
