//! Engine worker threads + the TCP accept loop.
//!
//! Two layers of parallelism compose here: `n_workers` engines (each with
//! its own model + cache, fed by the session-affinity router), and inside
//! each native engine an optional decode pool (`EngineOpts::decode_workers`)
//! that fans every decode iteration over balanced cache-length shards.
//! The factory decides the per-engine pool width; `serve` just reports it.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::router::Router;
use crate::coordinator::{Completion, Engine, Request};
use crate::util::json::{self, num, obj, Value};

/// Builds one engine per worker (engines are not Send-shareable across
/// workers by design — each owns its model and cache).
pub type EngineFactory = Arc<dyn Fn(usize) -> Engine + Send + Sync>;

enum Job {
    Run { req: Request, reply: Sender<Completion> },
    /// Admin introspection: the worker answers with its counters
    /// immediately, even mid-batch.
    Metrics { reply: Sender<Value> },
}

/// Submit a job to the engine; a rejected request gets an explicit
/// `rejected` reply with the `AdmitDecision` reason instead of a silently
/// dropped `Sender` (which left `handle_conn` waiting on a channel that
/// could never deliver).  EVERY path that submits must go through here.
fn submit_job(engine: &mut Engine, job: Job, replies: &mut HashMap<u64, Sender<Completion>>) {
    match job {
        Job::Run { req, reply } => {
            let id = req.id;
            let prompt_len = req.prompt.len();
            match engine.submit(req) {
                Ok(()) => {
                    replies.insert(id, reply);
                }
                Err(why) => {
                    let _ = reply.send(Completion::rejected(id, prompt_len, why));
                }
            }
        }
        Job::Metrics { reply } => {
            let _ = reply.send(metrics_value(engine));
        }
    }
}

/// One worker's counters as a JSON object.  Tier values come straight
/// from the pool (not the per-step metric gauges) so an admin query after
/// the last step still sees the final promotion/demotion counts.
fn metrics_value(engine: &Engine) -> Value {
    let m = &engine.metrics;
    let pool = engine.page_pool();
    obj(vec![
        ("requests_submitted", num(m.requests_submitted as f64)),
        ("requests_finished", num(m.requests_finished as f64)),
        ("requests_rejected", num(m.requests_rejected as f64)),
        ("prefill_tokens", num(m.prefill_tokens as f64)),
        ("decode_tokens", num(m.decode_tokens as f64)),
        ("prefix_hits", num(m.prefix_hits as f64)),
        ("prefix_tokens_reused", num(m.prefix_tokens_reused as f64)),
        ("preemptions", num(m.preemptions as f64)),
        ("pages_in_use", num(pool.pages_in_use() as f64)),
        ("pages_evicted", num(pool.pages_evicted() as f64)),
        ("tier_hits", num(pool.tier_hits() as f64)),
        ("pages_demoted", num(pool.pages_demoted() as f64)),
        ("pages_promoted", num(pool.pages_promoted() as f64)),
        ("bytes_on_disk", num(pool.bytes_on_disk() as f64)),
        ("snapkv_tokens_dropped", num(m.snapkv_tokens_dropped as f64)),
        ("summary", json::s(&m.summary())),
    ])
}

fn worker_loop(engine: &mut Engine, rx: Receiver<Job>, shutdown: &AtomicBool) {
    let mut replies: HashMap<u64, Sender<Completion>> = HashMap::new();
    loop {
        // drain new jobs; block briefly when idle
        loop {
            match rx.try_recv() {
                Ok(job) => submit_job(engine, job, &mut replies),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if engine.idle() {
                        return;
                    }
                    break;
                }
            }
        }
        if engine.idle() {
            if shutdown.load(Ordering::Relaxed) {
                return;
            }
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(job) => submit_job(engine, job, &mut replies),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
        match engine.step() {
            Ok(completions) => {
                for c in completions {
                    if let Some(tx) = replies.remove(&c.id) {
                        let _ = tx.send(c);
                    }
                }
            }
            Err(e) => {
                eprintln!("engine step error: {e:#}");
                return;
            }
        }
    }
}

/// A running server: listener thread + engine workers.
pub struct ServerHandle {
    pub addr: String,
    workers: Vec<JoinHandle<()>>,
    listener_thread: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Signal shutdown and join all threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // poke the listener so accept() returns
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Block until the server shuts down on its own — i.e. until a
    /// client sends `{"admin": "shutdown"}` and every worker drains,
    /// snapshots its tier, and exits.  The `serve` subcommand parks on
    /// this instead of sleeping forever, so graceful shutdown (and the
    /// tier snapshot it triggers) is reachable over the wire.
    pub fn wait(mut self) {
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.shutdown.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
    }
}

/// Start a server on `addr` ("127.0.0.1:0" for an ephemeral port) with
/// `n_workers` engines.  Returns once the listener is bound.
pub fn serve(factory: EngineFactory, addr: &str, n_workers: usize) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?.to_string();
    let shutdown = Arc::new(AtomicBool::new(false));

    let mut senders = Vec::new();
    let mut workers = Vec::new();
    for w in 0..n_workers {
        let (tx, rx) = channel::<Job>();
        senders.push(tx);
        let factory = factory.clone();
        let sd = shutdown.clone();
        workers.push(std::thread::spawn(move || {
            let mut engine = factory(w);
            if engine.decode_pool_width() > 1 {
                eprintln!(
                    "[server] engine {w}: decode pool width {}",
                    engine.decode_pool_width()
                );
            }
            if engine.prefill_chunk_size() > 0 {
                eprintln!(
                    "[server] engine {w}: chunked prefill, {} tokens/step",
                    engine.prefill_chunk_size()
                );
            }
            if engine.cache_pages() > 0 {
                eprintln!(
                    "[server] engine {w}: page pool capped at {} group-pages \
                     (preemptive eviction on exhaustion)",
                    engine.cache_pages()
                );
            }
            if engine.prefix_caching() {
                eprintln!("[server] engine {w}: prefix caching ON (refcounted page sharing)");
            }
            if let Some(t) = engine.tier() {
                eprintln!(
                    "[server] engine {w}: tiered page store at {} ({} prefix entries \
                     restored, {} bytes on disk, snapshot {})",
                    t.dir.display(),
                    engine.tier_restored(),
                    engine.page_pool().bytes_on_disk(),
                    if t.snapshot { "on" } else { "off" },
                );
            }
            worker_loop(&mut engine, rx, &sd);
            // graceful exit: persist the prefix cache for the next boot
            match engine.snapshot_tier() {
                Ok(Some((entries, bytes))) => eprintln!(
                    "[server] engine {w}: tier snapshot written ({entries} prefix entries, \
                     {bytes} bytes on disk)"
                ),
                Ok(None) => {}
                Err(e) => eprintln!("[server] engine {w}: tier snapshot failed: {e:#}"),
            }
        }));
    }
    let router = Arc::new(Mutex::new(Router::new(n_workers)));
    let next_id = Arc::new(Mutex::new(0u64));

    let sd = shutdown.clone();
    let listener_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if sd.load(Ordering::Relaxed) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let senders = senders.clone();
            let router = router.clone();
            let next_id = next_id.clone();
            let sd = sd.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, &senders, &router, &next_id, &sd);
            });
        }
    });

    Ok(ServerHandle {
        addr: local,
        workers,
        listener_thread: Some(listener_thread),
        shutdown,
    })
}

/// Answer an `{"admin": ...}` request.  `metrics` fans out to every
/// worker and returns both the per-worker objects and fleet totals for
/// the counters monitoring cares about; `shutdown` flips the flag that
/// makes each worker exit (and snapshot its tier) once idle.
fn handle_admin(cmd: &str, senders: &[Sender<Job>], shutdown: &AtomicBool) -> Value {
    match cmd {
        "shutdown" => {
            shutdown.store(true, Ordering::Relaxed);
            obj(vec![("admin", json::s("shutdown")), ("ok", Value::Bool(true))])
        }
        "metrics" => {
            let mut per_worker = Vec::new();
            for s in senders {
                let (tx, rx) = channel();
                if s.send(Job::Metrics { reply: tx }).is_ok() {
                    if let Ok(v) = rx.recv_timeout(Duration::from_secs(10)) {
                        per_worker.push(v);
                    }
                }
            }
            const TOTALS: &[&str] = &[
                "requests_finished",
                "requests_rejected",
                "prefill_tokens",
                "decode_tokens",
                "prefix_hits",
                "prefix_tokens_reused",
                "preemptions",
                "pages_in_use",
                "pages_evicted",
                "tier_hits",
                "pages_demoted",
                "pages_promoted",
                "bytes_on_disk",
                "snapkv_tokens_dropped",
            ];
            let mut fields: Vec<(&str, Value)> =
                vec![("admin", json::s("metrics")), ("ok", Value::Bool(true))];
            for &key in TOTALS {
                let total: f64 = per_worker
                    .iter()
                    .map(|w| w.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0))
                    .sum();
                fields.push((key, num(total)));
            }
            fields.push(("workers", Value::Arr(per_worker)));
            obj(fields)
        }
        other => obj(vec![
            ("ok", Value::Bool(false)),
            ("error", json::s(&format!("unknown admin command '{other}'"))),
        ]),
    }
}

fn handle_conn(
    stream: TcpStream,
    senders: &[Sender<Job>],
    router: &Arc<Mutex<Router>>,
    next_id: &Arc<Mutex<u64>>,
    shutdown: &AtomicBool,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                writeln!(stream, "{}", json::write(&obj(vec![("error", json::s(&e.0))])))?;
                continue;
            }
        };
        if let Some(cmd) = v.get("admin").and_then(|a| a.as_str()) {
            let reply = handle_admin(cmd, senders, shutdown);
            writeln!(stream, "{}", json::write(&reply))?;
            continue;
        }
        let prompt: Vec<u32> = v
            .get("prompt")
            .and_then(|p| p.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).map(|x| x as u32).collect())
            .unwrap_or_default();
        let max_tokens = v.usize_or("max_tokens", 16);
        let session = v.get("session").and_then(|s| s.as_i64()).map(|s| s as u64);

        let id = {
            let mut n = next_id.lock().unwrap();
            *n += 1;
            *n
        };
        let worker = router.lock().unwrap().route(session);
        let mut req = Request::greedy(id, prompt, max_tokens);
        req.session = session;
        let (tx, rx) = channel();
        senders[worker]
            .send(Job::Run { req, reply: tx })
            .map_err(|_| anyhow::anyhow!("worker {} gone", worker))?;
        let completion = rx.recv().context("worker dropped reply")?;
        router.lock().unwrap().complete(worker);

        let tokens = Value::Arr(
            completion.tokens.iter().map(|&t| num(t as f64)).collect(),
        );
        let mut fields = vec![
            ("id", num(id as f64)),
            ("worker", num(worker as f64)),
            ("prompt_len", num(completion.prompt_len as f64)),
            ("tokens", tokens),
            ("ttft_ms", num(completion.ttft_s.unwrap_or(0.0) * 1e3)),
            ("total_ms", num(completion.total_s.unwrap_or(0.0) * 1e3)),
            ("truncated", Value::Bool(completion.truncated)),
            ("rejected", Value::Bool(completion.rejected)),
        ];
        if let Some(reason) = completion.reason {
            fields.push(("reason", json::s(reason)));
        }
        let reply = obj(fields);
        writeln!(stream, "{}", json::write(&reply))?;
    }
}
