//! Engine worker threads + the TCP accept loop.
//!
//! Two layers of parallelism compose here: `n_workers` engines (each with
//! its own model + cache, fed by the session-affinity router), and inside
//! each native engine an optional decode pool (`EngineOpts::decode_workers`)
//! that fans every decode iteration over balanced cache-length shards.
//! The factory decides the per-engine pool width; `serve` just reports it.
//!
//! Two wire protocols share the JSON-lines framing (see the module docs
//! in [`super`] and the README's "Wire protocol v2" section):
//!
//! * **v1** (no `"v"` field): one-shot request -> one reply line.  Kept
//!   byte-compatible; the engine runs the identical greedy computation.
//! * **v2** (`"v": 2`): streaming generation (one line per engine
//!   [`Event`]), mid-stream `{"cancel": id}`, and session open / turn /
//!   close frames for multi-turn KV reuse.  Each streaming request gets a
//!   forwarder thread pumping engine events to the (line-locked) socket,
//!   so the connection loop keeps reading — that is what makes
//!   cancellation reachable mid-stream.

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::router::Router;
use crate::coordinator::{Completion, Engine, Event, GenOptions, Request, RequestId, SchedMode};
use crate::trace::prometheus::{render_fleet, PromFamily, PromKind};
use crate::trace::{chrome, TraceEvent, TraceRecorder};
use crate::util::json::{self, num, obj, Value};

/// Builds one engine per worker (engines are not Send-shareable across
/// workers by design — each owns its model and cache).
pub type EngineFactory = Arc<dyn Fn(usize) -> Engine + Send + Sync>;

enum Job {
    /// v1 one-shot: reply with the final completion only.
    Run { req: Request, reply: Sender<Completion> },
    /// v2: the engine streams events straight into `events`.
    Stream { req: Request, events: Sender<Event> },
    /// v2 session turn (`req.prompt` = the turn's NEW tokens only).
    Turn { sid: u64, req: Request, events: Sender<Event> },
    /// v2 cancel; the in-flight request's stream gets Done(cancelled).
    Cancel { id: RequestId },
    /// v2 session close: frees the engine-side chain.
    EndSession { sid: u64 },
    /// Admin introspection: the worker answers with its counters
    /// immediately, even mid-batch.
    Metrics { reply: Sender<Value> },
    /// Admin introspection in Prometheus shape: the worker answers with
    /// its full metric-family list (the fleet renderer merges workers).
    Prometheus { reply: Sender<Vec<PromFamily>> },
}

/// Submit a job to the engine; a rejected request gets an explicit
/// `rejected` reply with the `AdmitDecision` reason instead of a silently
/// dropped `Sender` (which left `handle_conn` waiting on a channel that
/// could never deliver).  EVERY path that submits must go through here.
fn submit_job(engine: &mut Engine, job: Job, replies: &mut HashMap<u64, Sender<Completion>>) {
    match job {
        Job::Run { req, reply } => {
            let id = req.id;
            let prompt_len = req.prompt.len();
            match engine.submit(req) {
                Ok(()) => {
                    replies.insert(id, reply);
                }
                Err(why) => {
                    let _ = reply.send(Completion::rejected(id, prompt_len, why));
                }
            }
        }
        // the engine owns event delivery (incl. the Rejected event), so
        // nothing to track here
        Job::Stream { req, events } => {
            let _ = engine.submit_with_events(req, events);
        }
        Job::Turn { sid, req, events } => {
            let _ = engine.submit_turn(sid, req, events);
        }
        Job::Cancel { id } => {
            engine.cancel(id);
        }
        Job::EndSession { sid } => {
            engine.end_session(sid);
        }
        Job::Metrics { reply } => {
            let _ = reply.send(metrics_value(engine));
        }
        Job::Prometheus { reply } => {
            let _ = reply.send(prom_families(engine));
        }
    }
}

/// One numeric counter or gauge of a worker: the admin-JSON key, its
/// stable Prometheus identity, and the current value.
struct NumMetric {
    key: &'static str,
    prom: &'static str,
    help: &'static str,
    kind: PromKind,
    value: f64,
}

/// Every numeric counter/gauge of one worker — THE single key list.
/// The admin `metrics` reply's numeric section, the fleet TOTALS
/// aggregation, and the Prometheus exposition all derive from this
/// vector, so a counter added here shows up in all three at once (and
/// `totals_cover_every_numeric_metric` below fails the build's tests if
/// a new `Metrics` field is forgotten).  Tier values come straight from
/// the pool (not the per-step metric gauges) so an admin query after the
/// last step still sees the final promotion/demotion counts.
fn numeric_metrics(engine: &Engine) -> Vec<NumMetric> {
    let m = &engine.metrics;
    let pool = engine.page_pool();
    let c = |key, prom, help, value: f64| NumMetric {
        key,
        prom,
        help,
        kind: PromKind::Counter,
        value,
    };
    let g = |key, prom, help, value: f64| NumMetric {
        key,
        prom,
        help,
        kind: PromKind::Gauge,
        value,
    };
    vec![
        c("requests_submitted", "polarquant_requests_submitted_total",
          "requests admitted into an engine queue", m.requests_submitted as f64),
        c("requests_finished", "polarquant_requests_finished_total",
          "requests retired with a completion", m.requests_finished as f64),
        c("requests_rejected", "polarquant_requests_rejected_total",
          "requests refused at admission", m.requests_rejected as f64),
        c("requests_cancelled", "polarquant_requests_cancelled_total",
          "requests cancelled while queued or running", m.requests_cancelled as f64),
        c("session_turns", "polarquant_session_turns_total",
          "session turns admitted", m.session_turns as f64),
        c("session_tokens_reused", "polarquant_session_tokens_reused_total",
          "prompt tokens skipped by resuming a session's live chain",
          m.session_tokens_reused as f64),
        c("prefill_tokens", "polarquant_prefill_tokens_total",
          "prompt tokens prefilled", m.prefill_tokens as f64),
        c("prefill_chunks", "polarquant_prefill_chunks_total",
          "prefill chunk grants executed", m.prefill_chunks as f64),
        c("decode_tokens", "polarquant_decode_tokens_total",
          "tokens generated", m.decode_tokens as f64),
        c("decode_steps", "polarquant_decode_steps_total",
          "decode iterations that produced at least one token", m.decode_steps as f64),
        c("decode_batch_sum", "polarquant_decode_batch_sum_total",
          "sequences decoded, summed over decode iterations", m.decode_batch_sum as f64),
        c("prefix_hits", "polarquant_prefix_hits_total",
          "prompts that attached to already-pooled prefix pages", m.prefix_hits as f64),
        c("prefix_tokens_reused", "polarquant_prefix_tokens_reused_total",
          "prompt tokens skipped via shared prefix pages", m.prefix_tokens_reused as f64),
        c("preemptions", "polarquant_preemptions_total",
          "decoding sequences preempted under page-pool pressure", m.preemptions as f64),
        g("pages_in_use", "polarquant_pages_in_use",
          "physical group-pages resident in the pool", pool.pages_in_use() as f64),
        c("pages_evicted", "polarquant_pages_evicted_total",
          "refcount-zero cached pages reclaimed under pressure",
          pool.pages_evicted() as f64),
        c("tier_hits", "polarquant_tier_hits_total",
          "prefix lookups that promoted pages from the disk tier",
          pool.tier_hits() as f64),
        c("pages_demoted", "polarquant_pages_demoted_total",
          "cached pages spilled to the disk tier", pool.pages_demoted() as f64),
        c("pages_promoted", "polarquant_pages_promoted_total",
          "pages read back from the disk tier on a prefix hit",
          pool.pages_promoted() as f64),
        g("bytes_on_disk", "polarquant_tier_bytes_on_disk",
          "segment bytes held by the disk tier", pool.bytes_on_disk() as f64),
        g("tier_session_bytes", "polarquant_tier_session_bytes",
          "disk-tier bytes held by reaped session blobs", pool.session_bytes() as f64),
        c("fabric_prefix_hits", "polarquant_fabric_prefix_hits_total",
          "prefix lookups satisfied by a cross-node fabric fetch",
          pool.fabric_prefix_hits() as f64),
        c("fabric_pages_fetched", "polarquant_fabric_pages_fetched_total",
          "pages admitted from the shared prefix fabric",
          pool.fabric_pages_fetched() as f64),
        c("fabric_rejected", "polarquant_fabric_rejected_total",
          "fetched fabric records rejected by verification (each one \
           degraded to a cold prefill)", pool.fabric_rejected() as f64),
        c("fabric_published", "polarquant_fabric_published_total",
          "prefix records this node published to the fabric",
          pool.fabric_published() as f64),
        c("fabric_bytes_fetched", "polarquant_fabric_bytes_fetched_total",
          "raw record bytes fetched from the fabric (hit or rejected)",
          pool.fabric_bytes_fetched() as f64),
        c("snapkv_tokens_dropped", "polarquant_snapkv_tokens_dropped_total",
          "prompt tokens dropped by SnapKV compression", m.snapkv_tokens_dropped as f64),
        c("tenant_throttled", "polarquant_tenant_throttled_total",
          "requests rejected by a tenant's token bucket", m.tenant_throttled as f64),
        c("sessions_reaped", "polarquant_sessions_reaped_total",
          "idle session chains demoted to the disk tier", m.sessions_reaped as f64),
        c("sessions_restored", "polarquant_sessions_restored_total",
          "reaped session chains promoted back", m.sessions_restored as f64),
        c("speculative_rounds", "polarquant_speculative_rounds_total",
          "decode iterations that ran a speculative window", m.speculative_rounds as f64),
        c("speculative_drafted", "polarquant_speculative_drafted_total",
          "draft tokens proposed on the coarse plane", m.speculative_drafted as f64),
        c("speculative_accepted", "polarquant_speculative_accepted_total",
          "draft tokens the exact verification accepted", m.speculative_accepted as f64),
        c("trace_dropped", "polarquant_trace_dropped_total",
          "trace events evicted by the bounded ring", engine.trace().dropped() as f64),
    ]
}

/// The worker's full Prometheus family list: every counter/gauge from
/// [`numeric_metrics`], the engine's latency histograms (cumulative
/// `le` buckets in seconds), the per-tenant breakdown (`tenant` label),
/// uptime, and build info.  [`render_fleet`] adds the `worker` label.
fn prom_families(engine: &Engine) -> Vec<PromFamily> {
    let m = &engine.metrics;
    let mut fams: Vec<PromFamily> = numeric_metrics(engine)
        .into_iter()
        .map(|n| match n.kind {
            PromKind::Counter => PromFamily::counter(n.prom, n.help, n.value),
            _ => PromFamily::gauge(n.prom, n.help, n.value),
        })
        .collect();
    let hists: [(&'static str, &'static str, &crate::util::stats::LatencyHist); 7] = [
        ("polarquant_ttft_seconds", "time to first token", &m.ttft),
        ("polarquant_itl_seconds", "inter-token latency", &m.itl),
        ("polarquant_per_token_seconds", "decode-iteration wall time", &m.per_token),
        ("polarquant_e2e_seconds", "request end-to-end latency", &m.e2e),
        ("polarquant_queue_delay_seconds", "queue wait before admission", &m.queue_delay),
        ("polarquant_decode_stall_seconds",
         "decode time stalled behind prefill chunks", &m.decode_stall),
        ("polarquant_prefill_chunk_seconds",
         "wall time of one prefill chunk", &m.prefill_chunk_us),
    ];
    for (name, help, h) in hists {
        let mut fam = PromFamily::empty(name, help, PromKind::Histogram);
        fam.push_histogram(Vec::new(), &h.cumulative_buckets(), h.sum_secs(), h.count());
        fams.push(fam);
    }
    let mut adm = PromFamily::empty(
        "polarquant_tenant_admitted_total", "per-tenant requests admitted", PromKind::Counter);
    let mut thr = PromFamily::empty(
        "polarquant_tenant_throttled_requests_total",
        "per-tenant requests rejected by the token bucket", PromKind::Counter);
    let mut fin = PromFamily::empty(
        "polarquant_tenant_finished_total", "per-tenant requests finished", PromKind::Counter);
    let mut tok = PromFamily::empty(
        "polarquant_tenant_decode_tokens_total", "per-tenant tokens generated",
        PromKind::Counter);
    let mut itl = PromFamily::empty(
        "polarquant_tenant_itl_seconds", "per-tenant inter-token latency",
        PromKind::Histogram);
    for (name, t) in &m.tenants {
        let label = |k: &str| vec![(k.to_string(), name.clone())];
        adm.push(label("tenant"), t.admitted as f64);
        thr.push(label("tenant"), t.throttled as f64);
        fin.push(label("tenant"), t.finished as f64);
        tok.push(label("tenant"), t.decode_tokens as f64);
        itl.push_histogram(
            label("tenant"), &t.itl.cumulative_buckets(), t.itl.sum_secs(), t.itl.count());
    }
    // empty families still render their HELP/TYPE header, which is valid
    // exposition; keep them so scrapes see a stable family set
    fams.extend([adm, thr, fin, tok, itl]);
    fams.push(PromFamily::gauge(
        "polarquant_uptime_seconds",
        "seconds since this engine started",
        m.started.elapsed().as_secs_f64(),
    ));
    let mut build = PromFamily::empty(
        "polarquant_build_info", "build/runtime identity (value is always 1)", PromKind::Gauge);
    build.push(vec![("kernel".to_string(), engine.kernel_name().to_string())], 1.0);
    fams.push(build);
    fams
}

/// One worker's counters as a JSON object.  Every top-level numeric
/// field comes from [`numeric_metrics`] — the fleet TOTALS in
/// `handle_admin` sum exactly those — while non-summable values
/// (latency percentiles, kernel name, per-tenant breakdown) live under
/// non-numeric keys so the aggregation skips them structurally instead
/// of by whitelist.
fn metrics_value(engine: &Engine) -> Value {
    let m = &engine.metrics;
    // percentiles are NaN before the first sample; 0 keeps the reply
    // valid JSON (our writer would emit a bare NaN otherwise)
    let ms = |secs: f64| num(if secs.is_finite() { secs * 1e3 } else { 0.0 });
    let mut fields: Vec<(&'static str, Value)> =
        numeric_metrics(engine).into_iter().map(|n| (n.key, num(n.value))).collect();
    // per-request latency histograms (p50/p95/p99, milliseconds) —
    // nested: summing percentiles across workers would be meaningless
    fields.push((
        "latency",
        obj(vec![
            ("ttft_ms_p50", ms(m.ttft.p(50.0))),
            ("ttft_ms_p95", ms(m.ttft.p(95.0))),
            ("ttft_ms_p99", ms(m.ttft.p(99.0))),
            ("itl_ms_p50", ms(m.itl.p(50.0))),
            ("itl_ms_p95", ms(m.itl.p(95.0))),
            ("itl_ms_p99", ms(m.itl.p(99.0))),
        ]),
    ));
    // the QK score kernel actually running ("scalar" / "simd" /
    // "pjrt-graph")
    fields.push(("kernel", json::s(engine.kernel_name())));
    // per-tenant breakdown keyed by tenant name
    fields.push(("tenants", tenants_value(m)));
    fields.push(("summary", json::s(&m.summary())));
    obj(fields)
}

/// The per-tenant counters as `{name: {...}}`.  Tenant names are dynamic
/// keys, so the object is built directly instead of through `obj`.
fn tenants_value(m: &crate::coordinator::metrics::Metrics) -> Value {
    let ms = |secs: f64| num(if secs.is_finite() { secs * 1e3 } else { 0.0 });
    let mut map = std::collections::BTreeMap::new();
    for (name, t) in &m.tenants {
        map.insert(
            name.clone(),
            obj(vec![
                ("admitted", num(t.admitted as f64)),
                ("throttled", num(t.throttled as f64)),
                ("finished", num(t.finished as f64)),
                ("decode_tokens", num(t.decode_tokens as f64)),
                ("itl_ms_p50", ms(t.itl.p(50.0))),
                ("itl_ms_p99", ms(t.itl.p(99.0))),
            ]),
        );
    }
    Value::Obj(map)
}

fn worker_loop(engine: &mut Engine, rx: Receiver<Job>, shutdown: &AtomicBool) {
    let mut replies: HashMap<u64, Sender<Completion>> = HashMap::new();
    loop {
        // drain new jobs; block briefly when idle
        loop {
            match rx.try_recv() {
                Ok(job) => submit_job(engine, job, &mut replies),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if engine.idle() {
                        return;
                    }
                    break;
                }
            }
        }
        if engine.idle() {
            if shutdown.load(Ordering::Relaxed) {
                return;
            }
            // step() reaps while the engine is busy; an idle worker spins
            // here without stepping, so the TTL sweep must run explicitly
            // or sessions idling on an otherwise-quiet worker never reap
            engine.reap_idle_sessions();
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(job) => submit_job(engine, job, &mut replies),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
        match engine.step() {
            Ok(completions) => {
                for c in completions {
                    if let Some(tx) = replies.remove(&c.id) {
                        let _ = tx.send(c);
                    }
                }
            }
            Err(e) => {
                eprintln!("engine step error: {e:#}");
                return;
            }
        }
    }
}

/// A running server: listener thread + engine workers.
pub struct ServerHandle {
    pub addr: String,
    workers: Vec<JoinHandle<()>>,
    listener_thread: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    /// one span recorder per worker (disabled no-ops under `--trace off`)
    recorders: Arc<Vec<Arc<TraceRecorder>>>,
    /// write a Chrome trace_event file here once the workers exit
    chrome_export: Option<PathBuf>,
}

impl ServerHandle {
    /// Signal shutdown and join all threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // poke the listener so accept() returns
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.export_chrome();
    }

    /// Block until the server shuts down on its own — i.e. until a
    /// client sends `{"admin": "shutdown"}` and every worker drains,
    /// snapshots its tier, and exits.  The `serve` subcommand parks on
    /// this instead of sleeping forever, so graceful shutdown (and the
    /// tier snapshot it triggers) is reachable over the wire.
    pub fn wait(mut self) {
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.shutdown.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        self.export_chrome();
    }

    /// Drain whatever is still buffered in the rings into the Chrome
    /// trace file (`--trace-export chrome://PATH`); at most once.
    fn export_chrome(&mut self) {
        let Some(path) = self.chrome_export.take() else { return };
        let per_worker: Vec<Vec<TraceEvent>> =
            self.recorders.iter().map(|r| r.drain()).collect();
        match chrome::export(&path, &per_worker) {
            Ok(()) => eprintln!("[server] chrome trace written to {}", path.display()),
            Err(e) => eprintln!("[server] chrome trace export failed: {e}"),
        }
    }
}

/// Start a server on `addr` ("127.0.0.1:0" for an ephemeral port) with
/// `n_workers` engines.  Returns once the listener is bound.
pub fn serve(factory: EngineFactory, addr: &str, n_workers: usize) -> Result<ServerHandle> {
    serve_with_export(factory, addr, n_workers, None)
}

/// [`serve`] plus the Chrome trace export: when `chrome_export` is set,
/// whatever is still buffered in the trace rings at shutdown is written
/// there as Chrome `trace_event` JSON (load in `chrome://tracing` or
/// Perfetto).  Pointless without a tracing factory (`EngineOpts::trace`).
pub fn serve_with_export(
    factory: EngineFactory,
    addr: &str,
    n_workers: usize,
    chrome_export: Option<PathBuf>,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?.to_string();
    let shutdown = Arc::new(AtomicBool::new(false));
    // advisory drain flag ({"admin":"drain"}): the front tier reads it
    // via ping and stops placing NEW sessions here; in-flight requests
    // and established sessions keep running until shutdown
    let draining = Arc::new(AtomicBool::new(false));

    let mut senders = Vec::new();
    let mut workers = Vec::new();
    // engines are built inside their worker threads; each hands its span
    // recorder (admin `trace` + Chrome export drain the rings from the
    // outside) and a page-pool handle (peer fabric fetches are answered
    // from the connection threads) back through this channel
    let (rec_tx, rec_rx) = channel::<(usize, Arc<TraceRecorder>, crate::kvcache::PagePool)>();
    for w in 0..n_workers {
        let (tx, rx) = channel::<Job>();
        senders.push(tx);
        let factory = factory.clone();
        let sd = shutdown.clone();
        let rec_tx = rec_tx.clone();
        workers.push(std::thread::spawn(move || {
            let mut engine = factory(w);
            // make this worker's prefix index answer peer fetches even
            // when no fetch transport is configured (no-op if the
            // factory already attached one — the bind is once-only)
            engine.enable_fabric_export();
            let _ = rec_tx.send((w, engine.trace(), engine.page_pool().clone()));
            drop(rec_tx);
            eprintln!("[server] engine {w}: QK score kernel '{}'", engine.kernel_name());
            if engine.decode_pool_width() > 1 {
                eprintln!(
                    "[server] engine {w}: decode pool width {}",
                    engine.decode_pool_width()
                );
            }
            if engine.prefill_chunk_size() > 0 {
                eprintln!(
                    "[server] engine {w}: chunked prefill, {} tokens/step",
                    engine.prefill_chunk_size()
                );
            }
            if engine.cache_pages() > 0 {
                eprintln!(
                    "[server] engine {w}: page pool capped at {} group-pages \
                     (preemptive eviction on exhaustion)",
                    engine.cache_pages()
                );
            }
            if engine.prefix_caching() {
                eprintln!("[server] engine {w}: prefix caching ON (refcounted page sharing)");
            }
            if engine.speculate_k() > 0 {
                let bits = engine
                    .draft_spec()
                    .map(|d| format!("r{}/t{}", d.r_bits, d.t_bits))
                    .unwrap_or_else(|| "unset".into());
                eprintln!(
                    "[server] engine {w}: speculative decoding, K={} on draft plane {bits} \
                     (greedy requests only; output stays bit-identical)",
                    engine.speculate_k()
                );
            }
            if engine.sched_mode() == SchedMode::Wfq {
                eprintln!(
                    "[server] engine {w}: weighted-fair tenant scheduling (deficit stride)"
                );
            }
            if let Some(ttl) = engine.session_ttl() {
                eprintln!(
                    "[server] engine {w}: idle-session TTL {:.1}s (reap to disk tier)",
                    ttl.as_secs_f64()
                );
            }
            if let Some(t) = engine.tier() {
                eprintln!(
                    "[server] engine {w}: tiered page store at {} ({} prefix entries \
                     restored, {} bytes on disk, snapshot {})",
                    t.dir.display(),
                    engine.tier_restored(),
                    engine.page_pool().bytes_on_disk(),
                    if t.snapshot { "on" } else { "off" },
                );
            }
            if engine.page_pool().fabric_attached() {
                eprintln!(
                    "[server] engine {w}: shared prefix fabric attached \
                     (cross-node page fetch on cold prefix misses)"
                );
            }
            worker_loop(&mut engine, rx, &sd);
            // graceful exit: persist the prefix cache for the next boot
            match engine.snapshot_tier() {
                Ok(Some((entries, bytes))) => eprintln!(
                    "[server] engine {w}: tier snapshot written ({entries} prefix entries, \
                     {bytes} bytes on disk)"
                ),
                Ok(None) => {}
                Err(e) => eprintln!("[server] engine {w}: tier snapshot failed: {e:#}"),
            }
        }));
    }
    drop(rec_tx);
    // collect one recorder per worker (index-aligned so trace lines and
    // chrome tracks carry the right worker id); generous timeout covers
    // slow model loads, and a missing recorder means a factory panicked
    let mut by_worker: Vec<Option<Arc<TraceRecorder>>> = vec![None; n_workers];
    let mut pools: Vec<crate::kvcache::PagePool> = Vec::new();
    for _ in 0..n_workers {
        match rec_rx.recv_timeout(Duration::from_secs(300)) {
            Ok((w, rec, pool)) => {
                by_worker[w] = Some(rec);
                pools.push(pool);
            }
            Err(_) => break,
        }
    }
    let recorders: Arc<Vec<Arc<TraceRecorder>>> = Arc::new(
        by_worker.into_iter().map(|r| r.unwrap_or_else(TraceRecorder::disabled)).collect(),
    );
    let pools = Arc::new(pools);

    let router = Arc::new(Mutex::new(Router::new(n_workers)));
    let next_id = Arc::new(AtomicU64::new(0));
    // server-allocated session ids start high so they never collide with
    // client-chosen v1 affinity keys in the router's sticky map
    let next_session = Arc::new(AtomicU64::new(1 << 32));

    let sd = shutdown.clone();
    let recs = recorders.clone();
    let drn = draining.clone();
    let listener_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if sd.load(Ordering::Relaxed) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let senders = senders.clone();
            let router = router.clone();
            let next_id = next_id.clone();
            let next_session = next_session.clone();
            let sd = sd.clone();
            let recs = recs.clone();
            let drn = drn.clone();
            let pools = pools.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(
                    stream, &senders, &router, &next_id, &next_session, &sd, &drn, &recs, &pools,
                );
            });
        }
    });

    Ok(ServerHandle {
        addr: local,
        workers,
        listener_thread: Some(listener_thread),
        shutdown,
        recorders,
        chrome_export,
    })
}

/// Fleet totals over the per-worker metric objects: EVERY top-level
/// numeric field is summed, so a counter added to [`numeric_metrics`]
/// aggregates automatically — non-summable values (percentiles, kernel
/// name, tenants) are nested/non-numeric and skipped structurally.
/// No whitelist to forget.
fn fleet_totals(per_worker: &[Value]) -> BTreeMap<String, f64> {
    let mut totals = BTreeMap::new();
    for w in per_worker {
        if let Value::Obj(map) = w {
            for (key, val) in map {
                if let Value::Num(n) = val {
                    *totals.entry(key.clone()).or_insert(0.0) += n;
                }
            }
        }
    }
    totals
}

/// Answer an `{"admin": ...}` request with one or more reply lines.
/// `metrics` fans out to every worker and returns the per-worker objects
/// plus fleet totals of every numeric counter; `prometheus` renders the
/// same counters (plus histograms) in text exposition format; `trace`
/// drains every worker's span ring as JSON lines followed by a
/// terminator; `ping` is the fabric heartbeat (role, worker count, and
/// the drain flag); `drain` marks this node as draining — advisory: the
/// front tier stops placing NEW sessions here while in-flight work and
/// established sessions run to completion; `shutdown` flips the flag
/// that makes each worker exit (and snapshot its tier) once idle.
fn handle_admin(
    cmd: &str,
    senders: &[Sender<Job>],
    recorders: &[Arc<TraceRecorder>],
    shutdown: &AtomicBool,
    draining: &AtomicBool,
) -> Vec<Value> {
    match cmd {
        "shutdown" => {
            shutdown.store(true, Ordering::Relaxed);
            vec![obj(vec![("admin", json::s("shutdown")), ("ok", Value::Bool(true))])]
        }
        "ping" => vec![obj(vec![
            ("admin", json::s("ping")),
            ("ok", Value::Bool(true)),
            ("role", json::s("serve")),
            ("workers", num(senders.len() as f64)),
            ("draining", Value::Bool(draining.load(Ordering::Relaxed))),
        ])],
        "drain" => {
            draining.store(true, Ordering::Relaxed);
            vec![obj(vec![
                ("admin", json::s("drain")),
                ("ok", Value::Bool(true)),
                ("draining", Value::Bool(true)),
            ])]
        }
        "metrics" => {
            let mut per_worker = Vec::new();
            for s in senders {
                let (tx, rx) = channel();
                if s.send(Job::Metrics { reply: tx }).is_ok() {
                    if let Ok(v) = rx.recv_timeout(Duration::from_secs(10)) {
                        per_worker.push(v);
                    }
                }
            }
            let mut out = BTreeMap::new();
            out.insert("admin".to_string(), json::s("metrics"));
            out.insert("ok".to_string(), Value::Bool(true));
            for (key, total) in fleet_totals(&per_worker) {
                out.insert(key, num(total));
            }
            out.insert("workers".to_string(), Value::Arr(per_worker));
            vec![Value::Obj(out)]
        }
        "prometheus" => {
            // index-aligned fan-out: a dead worker contributes an empty
            // family list so the `worker` labels stay truthful
            let mut per_worker: Vec<Vec<PromFamily>> = Vec::new();
            for s in senders {
                let (tx, rx) = channel();
                let fams = if s.send(Job::Prometheus { reply: tx }).is_ok() {
                    rx.recv_timeout(Duration::from_secs(10)).unwrap_or_default()
                } else {
                    Vec::new()
                };
                per_worker.push(fams);
            }
            let text = render_fleet(&per_worker);
            vec![obj(vec![
                ("admin", json::s("prometheus")),
                ("ok", Value::Bool(true)),
                ("text", json::s(&text)),
            ])]
        }
        "trace" => {
            // one JSON line per event (worker order, seq order within a
            // worker — a request lives on one worker, so its lifecycle
            // reads top-to-bottom), then the terminator line
            let mut lines = Vec::new();
            let mut dropped = 0u64;
            for (w, rec) in recorders.iter().enumerate() {
                dropped += rec.dropped();
                for ev in rec.drain() {
                    lines.push(ev.value(w));
                }
            }
            let events = lines.len();
            lines.push(obj(vec![
                ("admin", json::s("trace")),
                ("ok", Value::Bool(true)),
                ("events", num(events as f64)),
                ("dropped", num(dropped as f64)),
            ]));
            lines
        }
        other => vec![obj(vec![
            ("ok", Value::Bool(false)),
            ("error", json::s(&format!("unknown admin command '{other}'"))),
        ])],
    }
}

/// Line-atomic shared writer: streaming forwarder threads and the
/// connection loop interleave whole frames, never partial lines.
type SharedStream = Arc<Mutex<TcpStream>>;

fn write_line(out: &SharedStream, v: &Value) -> std::io::Result<()> {
    let mut s = out.lock().unwrap();
    writeln!(s, "{}", json::write(v))
}

fn error_frame(msg: &str) -> Value {
    obj(vec![("error", json::s(msg))])
}

/// Token-id array field (`"prompt"` / `"turn"` / `"stop"`).
fn tokens_field(v: &Value, key: &str) -> Option<Vec<u32>> {
    v.get(key)
        .and_then(|p| p.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_usize()).map(|x| x as u32).collect())
}

/// `"seed"` accepts a decimal string (full u64 range) or a JSON number.
/// Numbers ride an f64 and round above 2^53 — silently running a
/// DIFFERENT seed than the client asked for would break the
/// bit-identical-rollout contract, so anything ambiguous is an error,
/// not a guess (matching the strict-parser convention elsewhere).
fn seed_field(v: &Value) -> Result<u64, String> {
    const F64_EXACT: f64 = (1u64 << 53) as f64;
    match v.get("seed") {
        None => Ok(0),
        Some(Value::Str(s)) => {
            s.parse().map_err(|_| format!("seed '{s}' is not a decimal u64"))
        }
        Some(n) => match n.as_f64() {
            Some(f) if f >= 0.0 && f.fract() == 0.0 && f <= F64_EXACT => Ok(f as u64),
            _ => Err(
                "seed must be a non-negative integer <= 2^53; send a decimal STRING \
                 for the full u64 range"
                    .to_string(),
            ),
        },
    }
}

/// Per-request generation options from a v1/v2 request frame; every
/// field defaults to the greedy v1 behavior.  `Err` carries a message
/// for the wire's error frame.
fn gen_options(v: &Value) -> Result<GenOptions, String> {
    Ok(GenOptions {
        max_new_tokens: v.usize_or("max_tokens", 16),
        temperature: v.f64_or("temperature", 0.0) as f32,
        top_k: v.usize_or("top_k", 0),
        top_p: v.f64_or("top_p", 1.0) as f32,
        seed: seed_field(v)?,
        stop_tokens: tokens_field(v, "stop").unwrap_or_default(),
        // logprobs cost two O(vocab) passes per token: only streamed
        // frames (or an explicit "logprobs": true) pay for them — the
        // one-shot reply carries no logprobs anyway
        logprobs: v.get("logprobs").and_then(|b| b.as_bool()).unwrap_or(false),
        snapkv: match v.usize_or("snapkv_budget", 0) {
            0 => None,
            budget => Some(crate::coordinator::SnapKvOpts {
                budget,
                window: v.usize_or("snapkv_window", 8),
            }),
        },
    })
}

/// The completion fields shared by the v1 reply and the v2 `done` frame.
fn completion_fields(c: &Completion, worker: usize) -> Vec<(&'static str, Value)> {
    let tokens = Value::Arr(c.tokens.iter().map(|&t| num(t as f64)).collect());
    let mut fields = vec![
        ("id", num(c.id as f64)),
        ("worker", num(worker as f64)),
        ("prompt_len", num(c.prompt_len as f64)),
        ("tokens", tokens),
        ("ttft_ms", num(c.ttft_s.unwrap_or(0.0) * 1e3)),
        ("total_ms", num(c.total_s.unwrap_or(0.0) * 1e3)),
        ("truncated", Value::Bool(c.truncated)),
        ("rejected", Value::Bool(c.rejected)),
        ("finish_reason", json::s(c.finish_reason.as_str())),
    ];
    if let Some(reason) = c.reason {
        fields.push(("reason", json::s(reason.as_str())));
    }
    fields
}

/// One engine [`Event`] as a v2 frame.
fn event_frame(ev: &Event, worker: usize) -> Value {
    let base = |event: &str| vec![("v", num(2.0)), ("event", json::s(event))];
    match ev {
        Event::Admitted { id } => {
            let mut f = base("admitted");
            f.push(("id", num(*id as f64)));
            f.push(("worker", num(worker as f64)));
            obj(f)
        }
        Event::PrefillProgress { id, done, total } => {
            let mut f = base("prefill");
            f.push(("id", num(*id as f64)));
            f.push(("done", num(*done as f64)));
            f.push(("total", num(*total as f64)));
            obj(f)
        }
        Event::Token { id, token, logprob, index } => {
            let mut f = base("token");
            f.push(("id", num(*id as f64)));
            f.push(("token", num(*token as f64)));
            f.push(("logprob", num(*logprob as f64)));
            f.push(("index", num(*index as f64)));
            obj(f)
        }
        Event::Done(c) => {
            let mut f = base("done");
            f.extend(completion_fields(c, worker));
            obj(f)
        }
        Event::Rejected { id, reason } => {
            let mut f = base("rejected");
            f.push(("id", num(*id as f64)));
            f.push(("reason", json::s(reason.as_str())));
            obj(f)
        }
    }
}

/// The connection's live request registry (id -> worker), shared with
/// the stream forwarders so finished requests stop being cancellable and
/// the map cannot grow without bound on a long-lived connection.
type ConnRequests = Arc<Mutex<HashMap<u64, usize>>>;

/// Pump one request's engine events to the socket until the terminal
/// frame (`done` / `rejected`), a dead socket, or a dead worker — then
/// decrement the router load EXACTLY ONCE and drop the id from the
/// connection's registry.  With `stream` off only the terminal frame is
/// written (the v2 non-streaming shape).
fn pump_events(
    id: u64,
    rx: Receiver<Event>,
    out: SharedStream,
    router: Arc<Mutex<Router>>,
    requests: ConnRequests,
    worker: usize,
    stream: bool,
) {
    let mut terminated = false;
    while let Ok(ev) = rx.recv() {
        let terminal = matches!(ev, Event::Done(_) | Event::Rejected { .. });
        // a failed write = client went away mid-stream: stop forwarding;
        // the engine finishes the request and its events fall on the floor
        if (stream || terminal) && write_line(&out, &event_frame(&ev, worker)).is_err() {
            terminated = true; // nobody is reading; don't write more
            break;
        }
        if terminal {
            terminated = true;
            break;
        }
    }
    if !terminated {
        // the worker died (engine step error) before finishing this
        // request: tell the client instead of leaving it blocked on read
        let _ = write_line(&out, &error_frame("worker terminated before the request finished"));
    }
    requests.lock().unwrap().remove(&id);
    router.lock().unwrap().complete(worker);
}

#[allow(clippy::too_many_arguments)]
fn handle_conn(
    stream: TcpStream,
    senders: &[Sender<Job>],
    router: &Arc<Mutex<Router>>,
    next_id: &Arc<AtomicU64>,
    next_session: &Arc<AtomicU64>,
    shutdown: &AtomicBool,
    draining: &AtomicBool,
    recorders: &[Arc<TraceRecorder>],
    pools: &[crate::kvcache::PagePool],
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let out: SharedStream = Arc::new(Mutex::new(stream));
    // live requests started on THIS connection: id -> worker (cancel
    // routing); forwarders prune their id at the terminal frame
    let my_requests: ConnRequests = Arc::new(Mutex::new(HashMap::new()));
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                write_line(&out, &error_frame(&e.0))?;
                continue;
            }
        };
        if let Some(cmd) = v.get("admin").and_then(|a| a.as_str()) {
            for reply in handle_admin(cmd, senders, recorders, shutdown, draining) {
                write_line(&out, &reply)?;
            }
            continue;
        }
        if v.get("peer").is_some() {
            handle_peer(&v, &out, pools)?;
            continue;
        }
        match v.usize_or("v", 1) {
            1 => handle_v1(&v, &out, senders, router, next_id)?,
            2 => handle_v2(&v, &out, senders, router, next_id, next_session, &my_requests)?,
            other => write_line(&out, &error_frame(&format!(
                "unsupported protocol version {other} (this server speaks v1 and v2)"
            )))?,
        }
    }
}

/// Answer a peer node's `{"peer":"fetch","hash":"<decimal u64>"}` frame:
/// a `{"peer":"fetch","len":N}` header line followed by N raw record
/// bytes (`len` 0 = miss, no bytes follow).  The record comes from the
/// first worker whose prefix index holds the chain hash RESIDENT —
/// tiered entries don't export, so remote traffic can never thrash the
/// local disk tier.  The hash rides as a decimal string because JSON
/// numbers are f64 on this wire and round above 2^53.
fn handle_peer(v: &Value, out: &SharedStream, pools: &[crate::kvcache::PagePool]) -> Result<()> {
    let cmd = v.get("peer").and_then(|p| p.as_str()).unwrap_or("");
    if cmd != "fetch" {
        write_line(out, &error_frame(&format!("unknown peer command '{cmd}'")))?;
        return Ok(());
    }
    let hash = v.get("hash").and_then(|h| h.as_str()).and_then(|s| s.parse::<u64>().ok());
    let Some(hash) = hash else {
        write_line(out, &error_frame("peer fetch needs a decimal-string hash"))?;
        return Ok(());
    };
    let record = pools.iter().find_map(|p| p.fabric_export(hash)).unwrap_or_default();
    // header + raw bytes under ONE lock so another frame can't interleave
    let mut s = out.lock().unwrap();
    writeln!(
        s,
        "{}",
        json::write(&obj(vec![
            ("peer", json::s("fetch")),
            ("len", num(record.len() as f64)),
        ]))
    )?;
    if !record.is_empty() {
        s.write_all(&record)?;
    }
    Ok(())
}

/// The v1 one-shot path, byte-compatible with the pre-streaming protocol
/// (plus the additive `finish_reason` field).
fn handle_v1(
    v: &Value,
    out: &SharedStream,
    senders: &[Sender<Job>],
    router: &Arc<Mutex<Router>>,
    next_id: &Arc<AtomicU64>,
) -> Result<()> {
    let prompt = tokens_field(v, "prompt").unwrap_or_default();
    let session = v.get("session").and_then(|s| s.as_i64()).map(|s| s as u64);
    let gen = match gen_options(v) {
        Ok(g) => g,
        Err(e) => {
            write_line(out, &error_frame(&e))?;
            return Ok(());
        }
    };

    let id = next_id.fetch_add(1, Ordering::Relaxed) + 1;
    let worker = router.lock().unwrap().route(session);
    let mut req = Request::new(id, prompt, gen);
    req.session = session;
    let (tx, rx) = channel();
    // complete() must run exactly once per route() even when the worker
    // is gone — collect the result first, decrement, then propagate
    let completion = senders[worker]
        .send(Job::Run { req, reply: tx })
        .map_err(|_| anyhow::anyhow!("worker {} gone", worker))
        .and_then(|()| rx.recv().context("worker dropped reply"));
    router.lock().unwrap().complete(worker);
    let completion = completion?;
    write_line(out, &obj(completion_fields(&completion, worker)))?;
    Ok(())
}

/// v2 frames: `open_session` / `close` / `cancel` control frames answer
/// inline; `prompt` / `turn` submissions stream through `pump_events`.
#[allow(clippy::too_many_arguments)]
fn handle_v2(
    v: &Value,
    out: &SharedStream,
    senders: &[Sender<Job>],
    router: &Arc<Mutex<Router>>,
    next_id: &Arc<AtomicU64>,
    next_session: &Arc<AtomicU64>,
    my_requests: &ConnRequests,
) -> Result<()> {
    // -- session open ---------------------------------------------------
    if v.get("open_session").and_then(|b| b.as_bool()).unwrap_or(false) {
        let sid = next_session.fetch_add(1, Ordering::Relaxed);
        write_line(out, &obj(vec![
            ("v", num(2.0)),
            ("event", json::s("session")),
            ("session", num(sid as f64)),
            ("ok", Value::Bool(true)),
        ]))?;
        return Ok(());
    }
    // -- cancel ---------------------------------------------------------
    if let Some(id) = v.get("cancel").and_then(|c| c.as_usize()) {
        let id = id as u64;
        // fire-and-forget BY DESIGN: an inline ack frame would race the
        // request's own forwarder for the stream mutex (an ack landing
        // after `done` desyncs every later reply on the connection).
        // The observable answer is the cancelled request's terminal
        // frame; unknown/already-finished ids are silently ignored.
        if let Some(&worker) = my_requests.lock().unwrap().get(&id) {
            let _ = senders[worker].send(Job::Cancel { id });
        }
        return Ok(());
    }
    let session = v.get("session").and_then(|s| s.as_i64()).map(|s| s as u64);
    // -- session close --------------------------------------------------
    if v.get("close").and_then(|b| b.as_bool()).unwrap_or(false) {
        let Some(sid) = session else {
            write_line(out, &error_frame("close needs a session id"))?;
            return Ok(());
        };
        // idempotent: a session with no routed turn has no engine-side
        // state to free, so there is nothing to address
        let worker = router.lock().unwrap().session_worker(sid);
        if let Some(w) = worker {
            let _ = senders[w].send(Job::EndSession { sid });
        }
        router.lock().unwrap().end_session(sid);
        write_line(out, &obj(vec![
            ("v", num(2.0)),
            ("event", json::s("session_closed")),
            ("session", num(sid as f64)),
            ("ok", Value::Bool(true)),
        ]))?;
        return Ok(());
    }
    // -- generate / turn ------------------------------------------------
    let turn = tokens_field(v, "turn");
    let prompt = tokens_field(v, "prompt");
    if turn.is_some() && session.is_none() {
        write_line(out, &error_frame("turn needs a session id"))?;
        return Ok(());
    }
    if turn.is_none() && prompt.is_none() {
        write_line(out, &error_frame(
            "expected one of prompt, turn, cancel, open_session, close",
        ))?;
        return Ok(());
    }
    let mut gen = match gen_options(v) {
        Ok(g) => g,
        Err(e) => {
            write_line(out, &error_frame(&e))?;
            return Ok(());
        }
    };
    let stream = v.get("stream").and_then(|b| b.as_bool()).unwrap_or(false);
    gen.logprobs |= stream;
    // optional tenant identity; absent / empty -> the default tenant
    // (`Request::new` already carries it), so v1-shaped traffic and plain
    // v2 clients need no change
    let tenant = v.get("tenant").and_then(|t| t.as_str()).unwrap_or("");
    let id = next_id.fetch_add(1, Ordering::Relaxed) + 1;
    let worker = router.lock().unwrap().route(session);
    my_requests.lock().unwrap().insert(id, worker);
    let (tx, rx) = channel::<Event>();
    let job = match turn {
        Some(tokens) => {
            let mut req = Request::new(id, tokens, gen);
            req.session = session;
            if !tenant.is_empty() {
                req.tenant = tenant.to_string();
            }
            Job::Turn { sid: session.expect("checked above"), req, events: tx }
        }
        None => {
            let mut req = Request::new(id, prompt.expect("checked above"), gen);
            req.session = session;
            if !tenant.is_empty() {
                req.tenant = tenant.to_string();
            }
            Job::Stream { req, events: tx }
        }
    };
    if senders[worker].send(job).is_err() {
        my_requests.lock().unwrap().remove(&id);
        router.lock().unwrap().complete(worker);
        write_line(out, &error_frame(&format!("worker {worker} gone")))?;
        return Ok(());
    }
    if stream {
        // forwarder thread: the connection loop keeps reading, so a
        // {"cancel": id} frame can land mid-stream
        let out = out.clone();
        let router = router.clone();
        let requests = my_requests.clone();
        std::thread::spawn(move || pump_events(id, rx, out, router, requests, worker, true));
    } else {
        // one-shot v2: block until the terminal frame
        pump_events(id, rx, out.clone(), router.clone(), my_requests.clone(), worker, false);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::coordinator::EngineOpts;
    use crate::model::ModelConfig;
    use crate::util::stats::LatencyHist;
    use std::time::Instant;

    fn test_engine() -> Engine {
        Engine::native_synthetic(ModelConfig::tiny(), 1, 4.0, EngineOpts::default())
    }

    /// Guard for the single-key-list invariant: every numeric `Metrics`
    /// counter must surface as a top-level numeric field of the admin
    /// reply — and therefore in the fleet TOTALS and the Prometheus
    /// exposition, which derive from the same [`numeric_metrics`] list.
    /// The struct literal is EXHAUSTIVE on purpose: adding a `Metrics`
    /// field breaks this test's compile until the new counter is wired
    /// through (the old whitelist just silently omitted it).
    #[test]
    fn totals_cover_every_numeric_metric() {
        let m = Metrics {
            started: Instant::now(),
            requests_submitted: 1,
            requests_finished: 2,
            requests_rejected: 3,
            requests_cancelled: 4,
            session_turns: 5,
            session_tokens_reused: 6,
            prefill_tokens: 7,
            prefill_chunks: 8,
            prefill_chunk_us: LatencyHist::new(),
            decode_tokens: 9,
            decode_steps: 10,
            decode_batch_sum: 11,
            ttft: LatencyHist::new(),
            itl: LatencyHist::new(),
            per_token: LatencyHist::new(),
            e2e: LatencyHist::new(),
            queue_delay: LatencyHist::new(),
            decode_stall: LatencyHist::new(),
            prefix_hits: 12,
            prefix_tokens_reused: 13,
            preemptions: 14,
            // the pool-backed gauges below are read LIVE from the page
            // pool by numeric_metrics, not from the struct: the values
            // here exist only to keep the literal exhaustive
            pages_in_use: 90,
            pages_evicted: 91,
            tier_hits: 92,
            pages_demoted: 93,
            pages_promoted: 94,
            bytes_on_disk: 95,
            snapkv_tokens_dropped: 15,
            tenant_throttled: 16,
            sessions_reaped: 17,
            sessions_restored: 18,
            tier_session_bytes: 96,
            speculative_rounds: 19,
            speculative_drafted: 20,
            speculative_accepted: 21,
            tenants: std::collections::BTreeMap::new(),
        };
        let mut eng = test_engine();
        eng.metrics = m;
        let v = metrics_value(&eng);
        let expected: &[(&str, f64)] = &[
            ("requests_submitted", 1.0),
            ("requests_finished", 2.0),
            ("requests_rejected", 3.0),
            ("requests_cancelled", 4.0),
            ("session_turns", 5.0),
            ("session_tokens_reused", 6.0),
            ("prefill_tokens", 7.0),
            ("prefill_chunks", 8.0),
            ("decode_tokens", 9.0),
            ("decode_steps", 10.0),
            ("decode_batch_sum", 11.0),
            ("prefix_hits", 12.0),
            ("prefix_tokens_reused", 13.0),
            ("preemptions", 14.0),
            ("snapkv_tokens_dropped", 15.0),
            ("tenant_throttled", 16.0),
            ("sessions_reaped", 17.0),
            ("sessions_restored", 18.0),
            ("speculative_rounds", 19.0),
            ("speculative_drafted", 20.0),
            ("speculative_accepted", 21.0),
        ];
        for &(key, want) in expected {
            assert_eq!(v.get(key).and_then(|x| x.as_f64()), Some(want), "{key}");
        }
        // pool-backed keys are present but read the fresh pool (all 0)
        let pool_keys = [
            "pages_in_use",
            "pages_evicted",
            "tier_hits",
            "pages_demoted",
            "pages_promoted",
            "bytes_on_disk",
            "tier_session_bytes",
            "fabric_prefix_hits",
            "fabric_pages_fetched",
            "fabric_rejected",
            "fabric_published",
            "fabric_bytes_fetched",
            "trace_dropped",
        ];
        for key in pool_keys {
            assert_eq!(v.get(key).and_then(|x| x.as_f64()), Some(0.0), "{key}");
        }
        // the fleet totals sum EVERY top-level numeric field — two
        // identical workers double each value, and nothing else appears
        let totals = fleet_totals(&[v.clone(), v]);
        assert_eq!(totals.len(), expected.len() + pool_keys.len());
        for &(key, want) in expected {
            assert_eq!(totals[key], 2.0 * want, "{key}");
        }
        // the old hand-maintained whitelist forgot this one
        assert_eq!(totals["requests_submitted"], 2.0);
    }

    /// The Prometheus exposition must carry every numeric counter (same
    /// single list), all six engine histograms, the per-tenant families,
    /// uptime, and build info — with stable `polarquant_` names.
    #[test]
    fn prometheus_renders_every_counter_and_histogram() {
        let mut eng = test_engine();
        eng.metrics.ttft.record_secs(0.012);
        eng.metrics.itl.record_secs(0.002);
        eng.metrics.tenant("acme").admitted = 3;
        let text = render_fleet(&[prom_families(&eng)]);
        for n in numeric_metrics(&eng) {
            assert!(text.contains(&format!("# TYPE {} ", n.prom)), "missing {}", n.prom);
        }
        for name in [
            "polarquant_ttft_seconds",
            "polarquant_itl_seconds",
            "polarquant_per_token_seconds",
            "polarquant_e2e_seconds",
            "polarquant_queue_delay_seconds",
            "polarquant_decode_stall_seconds",
            "polarquant_prefill_chunk_seconds",
        ] {
            assert!(text.contains(&format!("# TYPE {name} histogram")), "missing {name}");
            assert!(text.contains(&format!("{name}_bucket")), "missing {name} buckets");
            assert!(
                text.contains(&format!("{name}_bucket{{le=\"+Inf\",worker=\"0\"}}")),
                "missing {name} +Inf closure"
            );
        }
        assert!(text.contains("polarquant_tenant_admitted_total{tenant=\"acme\",worker=\"0\"} 3"));
        assert!(text.contains("polarquant_uptime_seconds"));
        assert!(text.contains("polarquant_build_info{kernel=\""));
        // one recorded ttft sample lands in the histogram count
        assert!(text.contains("polarquant_ttft_seconds_count{worker=\"0\"} 1"));
    }

    /// Admin `trace` lines drain in worker order; the drop counter rides
    /// the terminator.
    #[test]
    fn admin_trace_drains_rings_in_worker_order() {
        let r0 = Arc::new(TraceRecorder::new(true, 16));
        let r1 = Arc::new(TraceRecorder::new(true, 16));
        r0.record(5, crate::trace::TraceKind::Admitted);
        r1.record(6, crate::trace::TraceKind::Done { finish_reason: "stop", tokens: 2 });
        let recorders = vec![r0, r1];
        let shutdown = AtomicBool::new(false);
        let draining = AtomicBool::new(false);
        let lines = handle_admin("trace", &[], &recorders, &shutdown, &draining);
        assert_eq!(lines.len(), 3, "two events + terminator");
        assert_eq!(lines[0].str_or("event", ""), "admitted");
        assert_eq!(lines[0].usize_or("worker", 9), 0);
        assert_eq!(lines[1].str_or("event", ""), "done");
        assert_eq!(lines[1].usize_or("worker", 9), 1);
        let term = lines.last().unwrap();
        assert_eq!(term.str_or("admin", ""), "trace");
        assert_eq!(term.usize_or("events", 0), 2);
        assert_eq!(term.usize_or("dropped", 9), 0);
        // a second drain is empty but still well-formed
        let lines = handle_admin("trace", &[], &recorders, &shutdown, &draining);
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].usize_or("events", 9), 0);
    }

    /// `ping` reports role + drain state; `drain` flips the flag without
    /// touching shutdown (in-flight work keeps running).
    #[test]
    fn ping_and_drain_report_node_state() {
        let shutdown = AtomicBool::new(false);
        let draining = AtomicBool::new(false);
        let lines = handle_admin("ping", &[], &[], &shutdown, &draining);
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].str_or("role", ""), "serve");
        assert_eq!(lines[0].get("draining").and_then(|b| b.as_bool()), Some(false));
        let lines = handle_admin("drain", &[], &[], &shutdown, &draining);
        assert_eq!(lines[0].get("ok").and_then(|b| b.as_bool()), Some(true));
        assert!(draining.load(Ordering::Relaxed));
        assert!(!shutdown.load(Ordering::Relaxed), "drain is not shutdown");
        let lines = handle_admin("ping", &[], &[], &shutdown, &draining);
        assert_eq!(lines[0].get("draining").and_then(|b| b.as_bool()), Some(true));
    }
}
