//! Request-lifecycle tracing: a lock-cheap, bounded ring buffer of typed
//! span events, plus two exposition formats (Chrome `trace_event` JSON
//! and Prometheus text exposition — see [`chrome`] and [`prometheus`]).
//!
//! One [`TraceRecorder`] exists per worker engine.  Every layer of the
//! serving stack records into it — admission (`admitted`), chunked
//! prefill (`prefill_chunk`), the decode loop (`decode_step`),
//! speculative rounds (`speculative_round`), page-pool pressure
//! (`page_preempt`, `page_promote`), the background tier writer
//! (`page_demote`), session TTL reaping (`session_reap` /
//! `session_restore`), and retirement (`done`) — keyed by the request
//! id that is already echoed on every wire-v2 frame, so client-visible
//! frames and server-side spans correlate by `id`.
//!
//! Design constraints, in priority order:
//!
//! 1. **Never block or change the hot path.**  A disabled recorder
//!    (`--trace off`, the default) is a single branch on a plain `bool`;
//!    no lock is taken, no clock is read, no allocation happens.  Output
//!    is byte-identical with tracing on or off — tracing is
//!    observation-only.
//! 2. **Bounded memory.**  The ring holds at most `cap` events; at
//!    capacity the OLDEST event is dropped and `trace_dropped` counts
//!    it.  A forgotten `--trace on` can never OOM a server.
//! 3. **Cheap when enabled.**  The sequence number is an atomic
//!    `fetch_add` taken OUTSIDE the ring mutex; the critical section is
//!    a `VecDeque` push (plus a pop at capacity).  Concurrent recorders
//!    (decode-pool workers, the tier writer) may interleave pushes out
//!    of sequence order, so [`TraceRecorder::drain`] sorts by `seq`
//!    before handing events out.
//!
//! Exposition:
//! - `{"admin":"trace"}` drains every worker's ring as JSON lines
//!   (schema in the README's Observability section).
//! - `--trace-export chrome://PATH` writes whatever is still in the
//!   rings at graceful shutdown as Chrome `trace_event` JSON.
//! - `{"admin":"prometheus"}` renders counters/gauges/histograms in
//!   Prometheus text exposition format ([`prometheus`]).

pub mod chrome;
pub mod prometheus;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{num, obj, s, Value};

/// Late-binding handle to a worker's recorder.  The page pool and the
/// background tier writer are built before `serve` decides whether
/// tracing is on, so they hold a slot that the engine fills exactly
/// once; an unfilled slot records nothing.
pub type TraceSlot = Arc<OnceLock<Arc<TraceRecorder>>>;

/// A fresh, unfilled [`TraceSlot`].
pub fn trace_slot() -> TraceSlot {
    Arc::new(OnceLock::new())
}

// ------------------------------------------------------------- events

/// What happened.  Variants mirror the request lifecycle; field names
/// match the JSON keys they serialize to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// request admitted into the engine queue
    Admitted,
    /// one prefill chunk quantized (`start` = absolute token position of
    /// the chunk; whole-prompt prefill emits a single chunk at start 0;
    /// `us` = wall-clock model time for the chunk)
    PrefillChunk { start: u32, tokens: u32, us: u32 },
    /// one decode iteration produced a token for this request
    /// (`pos` = sequence length after the step; `us` = model time)
    DecodeStep { pos: u32, us: u32 },
    /// one speculative propose/verify round (`drafted` tokens proposed
    /// on the coarse plane, `accepted` of them verified exact)
    SpeculativeRound { drafted: u32, accepted: u32 },
    /// the background tier writer persisted a cold page to disk
    PageDemote { pages: u32 },
    /// a prefix lookup pulled pages back from the disk tier
    PagePromote { pages: u32 },
    /// a prefix lookup admitted pages fetched from the shared fabric
    /// (a peer node or the shared segment directory)
    FabricFetch { pages: u32 },
    /// page-pool exhaustion preempted this request (its pages freed;
    /// the request replays later, bit-identically)
    PagePreempt { pages: u32 },
    /// an idle session's KV chain was reaped to the disk tier
    SessionReap { session: u64 },
    /// a reaped session's KV chain was restored for its next turn
    SessionRestore { session: u64 },
    /// request retired (`finish_reason` as on the wire: stop | length |
    /// cancelled | rejected)
    Done { finish_reason: &'static str, tokens: u32 },
}

impl TraceKind {
    /// The wire label (the JSON `event` field).
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Admitted => "admitted",
            TraceKind::PrefillChunk { .. } => "prefill_chunk",
            TraceKind::DecodeStep { .. } => "decode_step",
            TraceKind::SpeculativeRound { .. } => "speculative_round",
            TraceKind::PageDemote { .. } => "page_demote",
            TraceKind::PagePromote { .. } => "page_promote",
            TraceKind::FabricFetch { .. } => "fabric_fetch",
            TraceKind::PagePreempt { .. } => "page_preempt",
            TraceKind::SessionReap { .. } => "session_reap",
            TraceKind::SessionRestore { .. } => "session_restore",
            TraceKind::Done { .. } => "done",
        }
    }

    /// Variant-specific JSON fields (the common envelope is added by
    /// [`TraceEvent::value`]).
    fn fields(&self, out: &mut Vec<(&'static str, Value)>) {
        match *self {
            TraceKind::Admitted => {}
            TraceKind::PrefillChunk { start, tokens, us } => {
                out.push(("start", num(start as f64)));
                out.push(("tokens", num(tokens as f64)));
                out.push(("us", num(us as f64)));
            }
            TraceKind::DecodeStep { pos, us } => {
                out.push(("pos", num(pos as f64)));
                out.push(("us", num(us as f64)));
            }
            TraceKind::SpeculativeRound { drafted, accepted } => {
                out.push(("drafted", num(drafted as f64)));
                out.push(("accepted", num(accepted as f64)));
            }
            TraceKind::PageDemote { pages }
            | TraceKind::PagePromote { pages }
            | TraceKind::FabricFetch { pages }
            | TraceKind::PagePreempt { pages } => out.push(("pages", num(pages as f64))),
            TraceKind::SessionReap { session } | TraceKind::SessionRestore { session } => {
                out.push(("session", num(session as f64)))
            }
            TraceKind::Done { finish_reason, tokens } => {
                out.push(("finish_reason", s(finish_reason)));
                out.push(("tokens", num(tokens as f64)));
            }
        }
    }
}

/// One recorded span event.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// per-recorder monotone sequence number (drain order)
    pub seq: u64,
    /// microseconds since the recorder's epoch (engine construction)
    pub ts_us: u64,
    /// the request this event belongs to; 0 = background work not tied
    /// to a request (tier demotion, session reaping)
    pub request: u64,
    pub kind: TraceKind,
}

impl TraceEvent {
    /// The JSON-lines shape drained by `{"admin":"trace"}`.
    pub fn value(&self, worker: usize) -> Value {
        let mut fields = vec![
            ("event", s(self.kind.name())),
            ("id", num(self.request as f64)),
            ("seq", num(self.seq as f64)),
            ("ts_us", num(self.ts_us as f64)),
            ("worker", num(worker as f64)),
        ];
        self.kind.fields(&mut fields);
        obj(fields)
    }
}

// ----------------------------------------------------------- recorder

/// Bounded drop-oldest ring of [`TraceEvent`]s; see the module docs for
/// the hot-path contract.
pub struct TraceRecorder {
    enabled: bool,
    cap: usize,
    epoch: Instant,
    seq: AtomicU64,
    dropped: AtomicU64,
    ring: Mutex<VecDeque<TraceEvent>>,
}

impl TraceRecorder {
    /// Per-worker ring capacity: ~64k events is minutes of steady-state
    /// decode at serving rates, and a few MB at worst.
    pub const DEFAULT_CAPACITY: usize = 65_536;

    pub fn new(enabled: bool, cap: usize) -> Self {
        TraceRecorder {
            enabled,
            cap: cap.max(1),
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            // a disabled recorder never allocates ring storage
            ring: Mutex::new(VecDeque::with_capacity(if enabled { cap.max(1) } else { 0 })),
        }
    }

    /// A recorder that records nothing (the `--trace off` default).
    pub fn disabled() -> Arc<Self> {
        Arc::new(TraceRecorder::new(false, 1))
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record one event.  The single `enabled` branch is the whole cost
    /// when tracing is off.
    #[inline]
    pub fn record(&self, request: u64, kind: TraceKind) {
        if !self.enabled {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let ts_us = self.epoch.elapsed().as_micros() as u64;
        let ev = TraceEvent { seq, ts_us, request, kind };
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        if ring.len() >= self.cap {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(ev);
    }

    /// Events evicted by the ring since construction (ever, not since
    /// the last drain).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Take every buffered event, ordered by sequence number.  Draining
    /// empties the ring: a second drain returns only events recorded in
    /// between.
    pub fn drain(&self) -> Vec<TraceEvent> {
        let mut events: Vec<TraceEvent> = {
            let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
            ring.drain(..).collect()
        };
        // concurrent recorders can interleave pushes out of seq order
        events.sort_by_key(|e| e.seq);
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn over_capacity_ring_drops_oldest_and_counts() {
        let r = TraceRecorder::new(true, 4);
        for i in 0..10u64 {
            r.record(i, TraceKind::Admitted);
        }
        assert_eq!(r.dropped(), 6, "10 events into a 4-slot ring drop 6");
        let events = r.drain();
        assert_eq!(events.len(), 4);
        // the survivors are the NEWEST four, in sequence order
        let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        let reqs: Vec<u64> = events.iter().map(|e| e.request).collect();
        assert_eq!(reqs, vec![6, 7, 8, 9]);
        // drained means drained
        assert!(r.drain().is_empty());
        assert_eq!(r.dropped(), 6, "draining does not reset the drop counter");
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = TraceRecorder::disabled();
        for i in 0..100u64 {
            r.record(i, TraceKind::DecodeStep { pos: 1, us: 0 });
        }
        assert!(r.drain().is_empty());
        assert_eq!(r.dropped(), 0);
        assert!(!r.enabled());
    }

    #[test]
    fn events_serialize_with_envelope_and_variant_fields() {
        let r = TraceRecorder::new(true, 16);
        r.record(7, TraceKind::PrefillChunk { start: 32, tokens: 16, us: 250 });
        r.record(7, TraceKind::Done { finish_reason: "stop", tokens: 5 });
        let events = r.drain();
        let v = events[0].value(3);
        assert_eq!(v.str_or("event", ""), "prefill_chunk");
        assert_eq!(v.usize_or("id", 0), 7);
        assert_eq!(v.usize_or("worker", 0), 3);
        assert_eq!(v.usize_or("start", 0), 32);
        assert_eq!(v.usize_or("tokens", 0), 16);
        assert_eq!(v.usize_or("us", 0), 250);
        let v = events[1].value(3);
        assert_eq!(v.str_or("event", ""), "done");
        assert_eq!(v.str_or("finish_reason", ""), "stop");
        assert!(events[1].seq > events[0].seq);
        assert!(events[1].ts_us >= events[0].ts_us);
    }

    #[test]
    fn trace_slot_binds_once() {
        let slot = trace_slot();
        assert!(slot.get().is_none());
        let rec = Arc::new(TraceRecorder::new(true, 8));
        assert!(slot.set(rec.clone()).is_ok());
        slot.get().unwrap().record(1, TraceKind::PageDemote { pages: 1 });
        assert!(slot.set(TraceRecorder::disabled()).is_err(), "second bind is refused");
        assert_eq!(rec.drain().len(), 1);
    }
}
