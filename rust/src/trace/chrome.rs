//! Chrome `trace_event` export (`--trace-export chrome://PATH`).
//!
//! Written once at graceful shutdown from whatever is still buffered in
//! each worker's ring (an `{"admin":"trace"}` drain consumes events, so
//! the file holds everything drained by nobody).  The output is the
//! JSON-object flavor of the trace-event format — load it in
//! `chrome://tracing` or <https://ui.perfetto.dev>.
//!
//! Mapping:
//! - each worker is one thread track (`tid` = worker index);
//! - a request's lifetime is an async span (`ph: "b"` on `admitted`,
//!   `ph: "e"` on `done`) whose async `id` is the request id, so
//!   overlapping requests nest correctly;
//! - every intermediate request event is an async instant (`ph: "n"`)
//!   on the same id, carrying its variant fields under `args`;
//! - background events (request 0: tier demotions, session reaps) are
//!   plain thread instants (`ph: "i"`).

use std::io::Write;
use std::path::Path;

use crate::util::json::{num, obj, s, Value};

use super::{TraceEvent, TraceKind};

/// One trace-event record.
fn record(ev: &TraceEvent, worker: usize) -> Value {
    let background = ev.request == 0;
    let ph = match ev.kind {
        _ if background => "i",
        TraceKind::Admitted => "b",
        TraceKind::Done { .. } => "e",
        _ => "n",
    };
    // the async span pair shares one name so the viewer pairs b/e;
    // everything else keeps its event label
    let name = if ph == "b" || ph == "e" { "request" } else { ev.kind.name() };
    let mut fields = vec![
        ("cat", s("request")),
        ("name", s(name)),
        ("ph", s(ph)),
        ("pid", num(1.0)),
        ("tid", num(worker as f64)),
        ("ts", num(ev.ts_us as f64)),
    ];
    if background {
        fields.push(("s", s("t"))); // thread-scoped instant
    } else {
        fields.push(("id", num(ev.request as f64)));
    }
    // variant fields reuse the JSON-lines shape under `args`
    let mut args = vec![("seq", num(ev.seq as f64))];
    ev.kind.fields(&mut args);
    fields.push(("args", obj(args)));
    obj(fields)
}

/// Render per-worker event lists as one `{"traceEvents": [...]}` blob.
pub fn render(per_worker: &[Vec<TraceEvent>]) -> Value {
    let mut events = Vec::new();
    for (worker, evs) in per_worker.iter().enumerate() {
        // name the worker track
        events.push(obj(vec![
            ("name", s("thread_name")),
            ("ph", s("M")),
            ("pid", num(1.0)),
            ("tid", num(worker as f64)),
            ("args", obj(vec![("name", s(&format!("engine-{worker}")))])),
        ]));
        for ev in evs {
            events.push(record(ev, worker));
        }
    }
    obj(vec![("traceEvents", Value::Arr(events))])
}

/// Write the trace file; parent directories must already exist.
pub fn export(path: &Path, per_worker: &[Vec<TraceEvent>]) -> std::io::Result<()> {
    let blob = crate::util::json::write(&render(per_worker));
    let mut f = std::fs::File::create(path)?;
    f.write_all(blob.as_bytes())?;
    f.write_all(b"\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_lifetimes_become_async_spans() {
        let evs = vec![
            TraceEvent { seq: 0, ts_us: 10, request: 7, kind: TraceKind::Admitted },
            TraceEvent {
                seq: 1,
                ts_us: 20,
                request: 7,
                kind: TraceKind::PrefillChunk { start: 0, tokens: 4, us: 0 },
            },
            TraceEvent { seq: 2, ts_us: 25, request: 0, kind: TraceKind::PageDemote { pages: 1 } },
            TraceEvent {
                seq: 3,
                ts_us: 30,
                request: 7,
                kind: TraceKind::Done { finish_reason: "stop", tokens: 3 },
            },
        ];
        let v = render(&[evs]);
        let arr = v.get("traceEvents").and_then(|a| a.as_arr()).unwrap();
        // metadata record + 4 events
        assert_eq!(arr.len(), 5);
        assert_eq!(arr[0].str_or("ph", ""), "M");
        assert_eq!(arr[1].str_or("ph", ""), "b");
        assert_eq!(arr[1].usize_or("id", 0), 7);
        assert_eq!(arr[2].str_or("ph", ""), "n");
        assert_eq!(arr[2].str_or("name", ""), "prefill_chunk");
        // background work is a thread instant with no async id
        assert_eq!(arr[3].str_or("ph", ""), "i");
        assert!(arr[3].get("id").is_none());
        assert_eq!(arr[4].str_or("ph", ""), "e");
        assert_eq!(arr[4].usize_or("ts", 0), 30);
    }
}
