//! Prometheus text exposition (`{"admin":"prometheus"}`).
//!
//! Workers answer the admin request with structured [`PromFamily`]
//! lists (built from the engine's `Metrics` / `TenantStats` /
//! `TierCounters` / speculative counters in `server/worker.rs`);
//! [`render_fleet`] merges the per-worker lists, stamps every sample
//! with a `worker` label, and renders text exposition format version
//! 0.0.4: `# HELP` / `# TYPE` once per family, counters suffixed
//! `_total`, histograms as cumulative `le`-labeled buckets (seconds)
//! with `_sum` / `_count`.
//!
//! Metric names are STABLE — dashboards depend on them.  Every name is
//! prefixed `polarquant_`; adding a family is fine, renaming one is a
//! breaking change.

use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PromKind {
    Counter,
    Gauge,
    Histogram,
}

impl PromKind {
    fn label(self) -> &'static str {
        match self {
            PromKind::Counter => "counter",
            PromKind::Gauge => "gauge",
            PromKind::Histogram => "histogram",
        }
    }
}

/// One exposition line: `name<suffix>{labels} value`.
#[derive(Clone, Debug)]
pub struct PromSample {
    /// `""` for scalar families; `"_bucket"` / `"_sum"` / `"_count"`
    /// for histogram series
    pub suffix: &'static str,
    /// label pairs in emission order (the fleet renderer appends
    /// `worker` last)
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// One metric family: a name, its metadata, and its samples.
#[derive(Clone, Debug)]
pub struct PromFamily {
    pub name: &'static str,
    pub help: &'static str,
    pub kind: PromKind,
    pub samples: Vec<PromSample>,
}

impl PromFamily {
    fn scalar(name: &'static str, help: &'static str, kind: PromKind, value: f64) -> Self {
        PromFamily {
            name,
            help,
            kind,
            samples: vec![PromSample { suffix: "", labels: Vec::new(), value }],
        }
    }

    pub fn counter(name: &'static str, help: &'static str, value: f64) -> Self {
        PromFamily::scalar(name, help, PromKind::Counter, value)
    }

    pub fn gauge(name: &'static str, help: &'static str, value: f64) -> Self {
        PromFamily::scalar(name, help, PromKind::Gauge, value)
    }

    /// An empty family to push labeled series into (per-tenant metrics).
    pub fn empty(name: &'static str, help: &'static str, kind: PromKind) -> Self {
        PromFamily { name, help, kind, samples: Vec::new() }
    }

    /// One labeled scalar series (e.g. per-tenant counters).
    pub fn push(&mut self, labels: Vec<(String, String)>, value: f64) {
        self.samples.push(PromSample { suffix: "", labels, value });
    }

    /// One labeled histogram series: CUMULATIVE `le` buckets in seconds
    /// (callers pass them already accumulated), the implicit `+Inf`
    /// bucket, `_sum`, and `_count`.
    pub fn push_histogram(
        &mut self,
        labels: Vec<(String, String)>,
        buckets: &[(f64, u64)],
        sum_secs: f64,
        count: u64,
    ) {
        for &(le, cum) in buckets {
            let mut l = labels.clone();
            l.push(("le".to_string(), fmt_value(le)));
            self.samples.push(PromSample { suffix: "_bucket", labels: l, value: cum as f64 });
        }
        let mut l = labels.clone();
        l.push(("le".to_string(), "+Inf".to_string()));
        self.samples.push(PromSample { suffix: "_bucket", labels: l, value: count as f64 });
        self.samples.push(PromSample { suffix: "_sum", labels: labels.clone(), value: sum_secs });
        self.samples.push(PromSample { suffix: "_count", labels, value: count as f64 });
    }
}

/// Prometheus label-value escaping: backslash, double-quote, newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Shortest exact decimal for a sample value (`17`, not `17.0`; floats
/// keep their full shortest representation).
fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn render_sample(out: &mut String, name: &str, s: &PromSample) {
    out.push_str(name);
    out.push_str(s.suffix);
    if !s.labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in s.labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(&escape_label(v));
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    out.push_str(&fmt_value(s.value));
    out.push('\n');
}

/// Merge per-worker family lists and render the exposition text.
///
/// Families with the same name merge into one block (`# HELP` /
/// `# TYPE` emitted once, metadata taken from the first worker that
/// reports the family); every sample gains a `worker` label.  Families
/// are emitted in name order so the output is deterministic.
pub fn render_fleet(per_worker: &[Vec<PromFamily>]) -> String {
    let mut merged: BTreeMap<&'static str, (&'static str, PromKind, Vec<(usize, PromSample)>)> =
        BTreeMap::new();
    for (worker, families) in per_worker.iter().enumerate() {
        for fam in families {
            let entry = merged.entry(fam.name).or_insert((fam.help, fam.kind, Vec::new()));
            for s in &fam.samples {
                entry.2.push((worker, s.clone()));
            }
        }
    }
    let mut out = String::new();
    for (name, (help, kind, samples)) in &merged {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {}\n", kind.label()));
        for (worker, s) in samples {
            let mut s = s.clone();
            s.labels.push(("worker".to_string(), worker.to_string()));
            render_sample(&mut out, name, &s);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_counters_and_gauges_with_worker_labels() {
        let w0 = vec![PromFamily::counter("polarquant_decode_tokens_total", "tokens", 10.0)];
        let w1 = vec![PromFamily::counter("polarquant_decode_tokens_total", "tokens", 7.0)];
        let text = render_fleet(&[w0, w1]);
        assert_eq!(
            text,
            "# HELP polarquant_decode_tokens_total tokens\n\
             # TYPE polarquant_decode_tokens_total counter\n\
             polarquant_decode_tokens_total{worker=\"0\"} 10\n\
             polarquant_decode_tokens_total{worker=\"1\"} 7\n"
        );
    }

    #[test]
    fn histogram_series_is_cumulative_and_closed_by_inf() {
        let mut fam =
            PromFamily::empty("polarquant_ttft_seconds", "time to first token", PromKind::Histogram);
        fam.push_histogram(Vec::new(), &[(0.001, 2), (0.01, 5)], 0.025, 6);
        let text = render_fleet(&[vec![fam]]);
        assert!(text.contains("polarquant_ttft_seconds_bucket{le=\"0.001\",worker=\"0\"} 2\n"));
        assert!(text.contains("polarquant_ttft_seconds_bucket{le=\"0.01\",worker=\"0\"} 5\n"));
        assert!(text.contains("polarquant_ttft_seconds_bucket{le=\"+Inf\",worker=\"0\"} 6\n"));
        assert!(text.contains("polarquant_ttft_seconds_sum{worker=\"0\"} 0.025\n"));
        assert!(text.contains("polarquant_ttft_seconds_count{worker=\"0\"} 6\n"));
        // buckets are monotone non-decreasing through +Inf
        let buckets: Vec<f64> = text
            .lines()
            .filter(|l| l.contains("_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "{buckets:?}");
    }

    #[test]
    fn label_values_are_escaped_and_tenant_labels_ride_through() {
        let mut fam = PromFamily::empty("polarquant_tenant_admitted_total", "per-tenant", PromKind::Counter);
        fam.push(vec![("tenant".to_string(), "we\"ird\\t\nenant".to_string())], 3.0);
        let text = render_fleet(&[vec![fam]]);
        assert!(
            text.contains("polarquant_tenant_admitted_total{tenant=\"we\\\"ird\\\\t\\nenant\",worker=\"0\"} 3\n"),
            "{text}"
        );
    }

    #[test]
    fn every_line_is_valid_exposition_syntax() {
        let mut fams = vec![
            PromFamily::counter("polarquant_requests_finished_total", "done", 2.0),
            PromFamily::gauge("polarquant_pages_in_use", "resident pages", 5.0),
        ];
        let mut h = PromFamily::empty("polarquant_itl_seconds", "inter-token", PromKind::Histogram);
        h.push_histogram(Vec::new(), &[(0.5, 1)], 0.4, 1);
        fams.push(h);
        for line in render_fleet(&[fams]).lines() {
            if line.starts_with('#') {
                assert!(line.starts_with("# HELP ") || line.starts_with("# TYPE "), "{line}");
                continue;
            }
            let name = line.split(['{', ' ']).next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name in {line}"
            );
            let value: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(value.is_finite());
        }
    }
}
