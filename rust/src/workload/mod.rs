//! Synthetic workloads.
//!
//! The paper evaluates on real checkpoints and datasets we cannot load
//! offline (DESIGN.md §3).  These generators reproduce the *structural*
//! properties the experiments depend on: channel-wise key outliers per
//! model profile, long-context prompts, needle-retrieval tasks, and
//! Poisson request arrivals.

pub mod activations;
pub mod requests;

pub use activations::{ActivationProfile, PROFILES};
pub use requests::{ArrivalTrace, PromptKind, RequestGen};
