//! Synthetic post-RoPE key/value activations with per-model outlier
//! profiles (substitute for real checkpoint activations; DESIGN.md §3).
//!
//! The paper's Figure 1(a) structure: a few channels carry activations
//! 10–50x larger than the rest, each outlier living on ONE dim of a RoPE
//! pair; Qwen2.5 additionally has attention-bias-induced outliers, making
//! it the hardest profile (token-wise methods collapse there, Table 1).

use crate::tensor::ops::{rope_freqs, rope_rotate_inplace};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct ActivationProfile {
    pub name: &'static str,
    /// magnitude of the channel outliers (in units of sigma)
    pub outlier_severity: f32,
    /// fraction of RoPE pairs carrying an outlier
    pub outlier_frac: f32,
    /// extra constant bias on outlier channels (qwen-style attention bias)
    pub bias: f32,
    /// weight-synthesis severity for model-level proxies
    pub weight_severity: f32,
}

/// The three model families of Table 1, by key-distribution difficulty.
pub const PROFILES: [ActivationProfile; 3] = [
    ActivationProfile {
        name: "llama2-like",
        outlier_severity: 4.0,
        outlier_frac: 0.0625,
        bias: 0.0,
        weight_severity: 3.0,
    },
    ActivationProfile {
        name: "llama31-like",
        outlier_severity: 8.0,
        outlier_frac: 0.0625,
        bias: 0.0,
        weight_severity: 6.0,
    },
    ActivationProfile {
        name: "qwen-like",
        outlier_severity: 24.0,
        outlier_frac: 0.125,
        bias: 8.0,
        weight_severity: 14.0,
    },
];

impl ActivationProfile {
    pub fn by_name(name: &str) -> Option<&'static ActivationProfile> {
        PROFILES.iter().find(|p| p.name == name)
    }

    /// Generate (tokens x d) post-RoPE keys with this profile's outliers.
    pub fn keys(&self, rng: &mut Rng, tokens: usize, d: usize, rope_base: f32) -> Vec<f32> {
        let mut k = rng.normal_vec(tokens * d);
        let n_pairs = d / 2;
        let n_out = ((n_pairs as f32 * self.outlier_frac) as usize).max(1);
        let chans = rng.choose_distinct(n_pairs, n_out);
        for &j in &chans {
            let sign = rng.sign();
            for n in 0..tokens {
                k[n * d + 2 * j] += sign * (self.outlier_severity + self.bias);
            }
        }
        let freqs = rope_freqs(d, rope_base);
        for n in 0..tokens {
            rope_rotate_inplace(&mut k[n * d..(n + 1) * d], n as u32, &freqs);
        }
        k
    }

    /// Values have no outlier structure (paper Appendix D).
    pub fn values(&self, rng: &mut Rng, tokens: usize, d: usize) -> Vec<f32> {
        rng.normal_vec(tokens * d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen_profile_has_bigger_channel_spread() {
        let mut rng = Rng::new(1);
        let d = 64;
        let easy = ActivationProfile::by_name("llama2-like").unwrap();
        let hard = ActivationProfile::by_name("qwen-like").unwrap();
        let spread = |k: &[f32]| {
            // max channel |mean| (pre-rope the outlier is a mean shift;
            // post-rope it smears across the pair, magnitude preserved)
            let t = k.len() / d;
            (0..d)
                .map(|j| {
                    let m: f32 = (0..t).map(|n| k[n * d + j].abs()).sum::<f32>() / t as f32;
                    m
                })
                .fold(0.0f32, f32::max)
        };
        let ke = easy.keys(&mut rng, 128, d, 10000.0);
        let kh = hard.keys(&mut rng, 128, d, 10000.0);
        assert!(spread(&kh) > 2.0 * spread(&ke));
    }

    #[test]
    fn rope_smears_outliers_across_pairs() {
        // post-RoPE, an outlier pair's energy oscillates between its two
        // dims but the pair magnitude is stable — the paper's key insight
        let mut rng = Rng::new(2);
        let p = ActivationProfile::by_name("llama31-like").unwrap();
        let d = 32;
        let k = p.keys(&mut rng, 256, d, 10000.0);
        // find the strongest pair
        let t = 256;
        let (mut best_j, mut best_m) = (0, 0.0f32);
        for j in 0..d / 2 {
            let m: f32 = (0..t)
                .map(|n| {
                    let x = k[n * d + 2 * j];
                    let y = k[n * d + 2 * j + 1];
                    (x * x + y * y).sqrt()
                })
                .sum::<f32>()
                / t as f32;
            if m > best_m {
                best_m = m;
                best_j = j;
            }
        }
        // pair radius variance is small relative to its mean
        let radii: Vec<f32> = (0..t)
            .map(|n| {
                let x = k[n * d + 2 * best_j];
                let y = k[n * d + 2 * best_j + 1];
                (x * x + y * y).sqrt()
            })
            .collect();
        let mean: f32 = radii.iter().sum::<f32>() / t as f32;
        let var: f32 =
            radii.iter().map(|r| (r - mean) * (r - mean)).sum::<f32>() / t as f32;
        assert!(var.sqrt() < 0.5 * mean, "std {} mean {mean}", var.sqrt());
    }
}
