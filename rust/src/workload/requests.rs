//! Request-level workloads: prompt generators (mixed lengths, needle
//! retrieval) and Poisson arrival traces for the serving benches.

use crate::coordinator::Request;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PromptKind {
    /// uniform random tokens of a given length
    Random { len: usize },
    /// a long haystack with one needle token; retrieval-style context
    Needle { len: usize, needle: u32 },
    /// mixed lengths drawn uniformly from [lo, hi)
    Mixed { lo: usize, hi: usize },
}

pub struct RequestGen {
    pub vocab: usize,
    pub rng: Rng,
    next_id: u64,
}

impl RequestGen {
    pub fn new(vocab: usize, seed: u64) -> Self {
        RequestGen { vocab, rng: Rng::new(seed), next_id: 0 }
    }

    pub fn prompt(&mut self, kind: PromptKind) -> Vec<u32> {
        match kind {
            PromptKind::Random { len } => {
                (0..len).map(|_| self.rng.below(self.vocab) as u32).collect()
            }
            PromptKind::Needle { len, needle } => {
                let mut p: Vec<u32> =
                    (0..len).map(|_| self.rng.below(self.vocab) as u32).collect();
                let pos = self.rng.below(len.saturating_sub(2).max(1));
                p[pos] = needle;
                p
            }
            PromptKind::Mixed { lo, hi } => {
                let len = self.rng.range(lo, hi);
                self.prompt(PromptKind::Random { len })
            }
        }
    }

    pub fn request(&mut self, kind: PromptKind, max_new: usize) -> Request {
        self.next_id += 1;
        Request::greedy(self.next_id, self.prompt(kind), max_new)
    }
}

/// Poisson arrivals: offsets (seconds from t0) for `n` requests at `rps`.
#[derive(Clone, Debug)]
pub struct ArrivalTrace {
    pub offsets: Vec<f64>,
}

impl ArrivalTrace {
    pub fn poisson(rng: &mut Rng, n: usize, rps: f64) -> Self {
        let mut t = 0.0;
        let mut offsets = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exponential(rps);
            offsets.push(t);
        }
        ArrivalTrace { offsets }
    }

    pub fn uniform(n: usize, rps: f64) -> Self {
        ArrivalTrace {
            offsets: (0..n).map(|i| i as f64 / rps).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needle_is_present() {
        let mut g = RequestGen::new(100, 1);
        let p = g.prompt(PromptKind::Needle { len: 50, needle: 99 });
        assert_eq!(p.len(), 50);
        assert!(p.contains(&99));
    }

    #[test]
    fn mixed_lengths_in_range() {
        let mut g = RequestGen::new(100, 2);
        for _ in 0..50 {
            let p = g.prompt(PromptKind::Mixed { lo: 5, hi: 20 });
            assert!((5..20).contains(&p.len()));
        }
    }

    #[test]
    fn poisson_rate_approximately_right() {
        let mut rng = Rng::new(3);
        let tr = ArrivalTrace::poisson(&mut rng, 2000, 10.0);
        let total = tr.offsets.last().unwrap();
        let rate = 2000.0 / total;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn ids_are_unique() {
        let mut g = RequestGen::new(100, 4);
        let a = g.request(PromptKind::Random { len: 4 }, 2);
        let b = g.request(PromptKind::Random { len: 4 }, 2);
        assert_ne!(a.id, b.id);
    }
}
