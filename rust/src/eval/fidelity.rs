//! Codec-level fidelity on profile-structured key activations.
//!
//! For each codec: encode keys, then measure
//!   * key reconstruction MSE / cosine (except QJL, which is score-only),
//!   * attention-weight KL(fp || quantized) and top-8 overlap over random
//!     queries — the quantity that actually drives downstream quality.

use crate::quant::QuantSpec;
use crate::tensor::ops::{cosine, dot, mse, softmax_inplace};
use crate::util::rng::Rng;
use crate::workload::ActivationProfile;

#[derive(Clone, Copy, Debug, Default)]
pub struct Fidelity {
    pub key_mse: f64,
    pub key_cos: f64,
    pub attn_kl: f64,
    pub top8_overlap: f64,
    pub score_mse: f64,
    pub bits: f64,
}

pub fn eval_codec(
    spec: &QuantSpec,
    profile: &ActivationProfile,
    d: usize,
    tokens: usize,
    n_queries: usize,
    seed: u64,
) -> Fidelity {
    let mut rng = Rng::new(seed);
    let k = profile.keys(&mut rng, tokens, d, 10000.0);
    let enc = spec.encode(&k, d);
    let scale = 1.0 / (d as f32).sqrt();

    let (key_mse_v, key_cos_v) = if matches!(spec, QuantSpec::Qjl { .. }) {
        (f64::NAN, f64::NAN)
    } else {
        let k_hat = enc.decode();
        (mse(&k, &k_hat), cosine(&k, &k_hat))
    };

    let mut kl_sum = 0.0;
    let mut overlap_sum = 0.0;
    let mut score_mse_sum = 0.0;
    let mut scores_q = Vec::new();
    for _ in 0..n_queries {
        let q = rng.normal_vec(d);
        // fp scores
        let mut scores_fp: Vec<f32> = (0..tokens)
            .map(|n| dot(&q, &k[n * d..(n + 1) * d]) * scale)
            .collect();
        enc.scores(&q, &mut scores_q);
        for s in scores_q.iter_mut() {
            *s *= scale;
        }
        score_mse_sum += mse(&scores_fp, &scores_q);
        let mut w_q = scores_q.clone();
        softmax_inplace(&mut scores_fp);
        softmax_inplace(&mut w_q);
        // KL(fp || q)
        let mut kl = 0.0f64;
        for i in 0..tokens {
            let p = scores_fp[i].max(1e-12) as f64;
            let qq = w_q[i].max(1e-12) as f64;
            kl += p * (p / qq).ln();
        }
        kl_sum += kl;
        // top-8 overlap
        overlap_sum += topk_overlap(&scores_fp, &w_q, 8);
    }
    Fidelity {
        key_mse: key_mse_v,
        key_cos: key_cos_v,
        attn_kl: kl_sum / n_queries as f64,
        top8_overlap: overlap_sum / n_queries as f64,
        score_mse: score_mse_sum / n_queries as f64,
        bits: spec.bits_per_element(d),
    }
}

fn topk_overlap(a: &[f32], b: &[f32], k: usize) -> f64 {
    let top = |x: &[f32]| {
        let mut idx: Vec<usize> = (0..x.len()).collect();
        idx.sort_by(|&i, &j| x[j].partial_cmp(&x[i]).unwrap());
        idx.truncate(k);
        idx
    };
    let ta = top(a);
    let tb = top(b);
    let inter = ta.iter().filter(|i| tb.contains(i)).count();
    inter as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::PROFILES;

    #[test]
    fn polar_beats_tokenwise_on_every_profile() {
        // Table 1's core ordering, at the fidelity level.
        for p in &PROFILES {
            let polar = eval_codec(
                &QuantSpec::Polar { r_bits: 4, t_bits: 4, group: 32 },
                p, 64, 128, 8, 42,
            );
            let int4 = eval_codec(&QuantSpec::Int { bits: 4 }, p, 64, 128, 8, 42);
            assert!(
                polar.attn_kl < int4.attn_kl,
                "{}: polar {} vs int {}",
                p.name,
                polar.attn_kl,
                int4.attn_kl
            );
        }
    }

    #[test]
    fn tokenwise_collapses_hardest_on_qwen_profile() {
        let easy = ActivationProfile::by_name("llama2-like").unwrap();
        let hard = ActivationProfile::by_name("qwen-like").unwrap();
        let e = eval_codec(&QuantSpec::Int { bits: 4 }, easy, 64, 128, 8, 7);
        let h = eval_codec(&QuantSpec::Int { bits: 4 }, hard, 64, 128, 8, 7);
        assert!(h.attn_kl > 2.0 * e.attn_kl, "{} vs {}", h.attn_kl, e.attn_kl);
    }

    #[test]
    fn fp_is_perfect() {
        let p = &PROFILES[0];
        let f = eval_codec(&QuantSpec::Fp16, p, 32, 64, 4, 1);
        assert!(f.key_mse < 1e-12);
        assert!(f.attn_kl < 1e-9);
        assert!((f.top8_overlap - 1.0).abs() < 1e-12);
    }
}
