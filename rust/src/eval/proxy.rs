//! Model-level quality proxy: greedy-decode agreement.
//!
//! For a codec C and a synthetic model M (outlier severity per profile),
//! run M in full precision (teacher) and M-with-C-quantized-keys (student)
//! over the same prompts, teacher-forcing the teacher's tokens, and
//! measure: argmax agreement rate + mean logit cosine.  This is the
//! mechanism behind the paper's Table 1/2/3 orderings — downstream score
//! drop is driven by how much the quantized attention perturbs the next-
//! token distribution.
//!
//! Implementation: "dequantize-then-fp-decode".  At every step the student
//! cache's keys are the codec's encode→decode round-trip of the true keys
//! (full groups only; the tail stays fp, matching the residual-buffer
//! semantics every method shares).  This is mathematically identical to
//! running each codec's own score path (scores are linear in the
//! dequantized keys) and lets one engine serve every codec.  QJL is
//! score-only (no key reconstruction) and is evaluated in `fidelity`.

use crate::kvcache::SequenceCache;
use crate::model::{Model, ModelConfig, Weights};
use crate::quant::QuantSpec;
use crate::tensor::ops::{argmax, cosine};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, Default)]
pub struct ProxyScore {
    /// fraction of steps where student argmax == teacher argmax
    pub agreement: f64,
    /// mean logit cosine over steps
    pub logit_cos: f64,
    pub steps: usize,
}

impl ProxyScore {
    /// Map to a paper-style 0-100 "task score" (agreement percentage).
    pub fn task_score(&self) -> f64 {
        self.agreement * 100.0
    }
}

/// Build a config whose cache never quantizes (group larger than any
/// sequence) — the fp twin.
fn fp_config(cfg: &ModelConfig) -> ModelConfig {
    let mut c = cfg.clone();
    c.group = 1 << 20;
    c.resid = 1 << 20;
    c
}

/// Round-trip the full-group prefix of `keys` through `codec`; the tail
/// stays fp.  `keys` is (t x d) for one stream.  The prefix is a whole
/// number of BOTH the engine's group and the codec's own group (KIVI-2
/// uses g=32 regardless of the engine setting, per the paper's setup).
fn roundtrip_prefix(codec: &QuantSpec, keys: &[f32], d: usize, group: usize) -> Vec<f32> {
    fn gcd(a: usize, b: usize) -> usize {
        if b == 0 { a } else { gcd(b, a % b) }
    }
    let group = match codec.group_size() {
        Some(cg) => cg / gcd(cg, group) * group,
        None => group,
    };
    let t = keys.len() / d;
    let full = (t / group) * group;
    let mut out = Vec::with_capacity(keys.len());
    if full > 0 {
        let enc = codec.encode(&keys[..full * d], d);
        out.extend_from_slice(&enc.decode());
    }
    out.extend_from_slice(&keys[full * d..]);
    out
}

/// Teacher-forced decode agreement for one codec on one synthetic model.
pub fn decode_agreement(
    cfg: &ModelConfig,
    weight_seed: u64,
    weight_severity: f32,
    codec: &QuantSpec,
    prompts: &[Vec<u32>],
    steps: usize,
) -> ProxyScore {
    decode_agreement_kv(cfg, weight_seed, weight_severity, codec, None, prompts, steps)
}

/// As [`decode_agreement`], with optional token-wise VALUE quantization on
/// the student (Tables 7 and 9).
pub fn decode_agreement_kv(
    cfg: &ModelConfig,
    weight_seed: u64,
    weight_severity: f32,
    codec: &QuantSpec,
    value_bits: Option<u32>,
    prompts: &[Vec<u32>],
    steps: usize,
) -> ProxyScore {
    let fp_cfg = fp_config(cfg);
    let weights = Weights::synthetic(&fp_cfg, weight_seed, weight_severity);
    let mut teacher = Model::new(fp_cfg.clone(), weights.clone());
    let mut student = Model::new(fp_cfg.clone(), weights);
    let group = cfg.group;
    let d = cfg.head_dim;

    let mut agree = 0usize;
    let mut cos_sum = 0.0f64;
    let mut total = 0usize;

    for prompt in prompts {
        // teacher: fp all the way
        let mut t_cache = SequenceCache::new(fp_cfg.cache_config(None));
        let t_logits = teacher.prefill(prompt, &mut t_cache);
        let mut t_tok = argmax(&t_logits) as u32;

        // student: same fp cache, but keys round-tripped through the codec
        // before every step
        let mut s_cache = SequenceCache::new(fp_cfg.cache_config(None));
        student.prefill(prompt, &mut s_cache);

        for _ in 0..steps {
            // quantize the student's key prefix (and optionally values)
            let mut sq = s_cache.clone();
            for st in sq.streams.iter_mut() {
                st.resid_k = roundtrip_prefix(codec, &st.resid_k, d, group);
                if let Some(bits) = value_bits {
                    let enc = crate::quant::value::encode(&st.resid_v, d, bits);
                    st.resid_v = crate::quant::value::decode(&enc, d);
                }
            }
            let s_logits = student.decode_step(t_tok, &mut sq).to_vec();
            let t_logits = teacher.decode_step(t_tok, &mut t_cache).to_vec();
            // persist the TRUE (fp) new keys into the student cache: take
            // the step's appended k/v from the teacher-free student pass
            // by re-appending to the un-quantized cache
            let lkv = fp_cfg.n_layers * fp_cfg.n_kv_heads;
            let mut new_k = vec![0.0f32; lkv * d];
            let mut new_v = vec![0.0f32; lkv * d];
            for (si, st) in sq.streams.iter().enumerate() {
                let r = st.resid_len() - 1;
                new_k[si * d..(si + 1) * d].copy_from_slice(&st.resid_k[r * d..(r + 1) * d]);
                new_v[si * d..(si + 1) * d].copy_from_slice(&st.resid_v[r * d..(r + 1) * d]);
            }
            s_cache.append_step(&new_k, &new_v);

            if argmax(&s_logits) == argmax(&t_logits) {
                agree += 1;
            }
            cos_sum += cosine(&s_logits, &t_logits);
            total += 1;
            t_tok = argmax(&t_logits) as u32; // teacher-forced
        }
    }
    ProxyScore {
        agreement: agree as f64 / total as f64,
        logit_cos: cos_sum / total as f64,
        steps: total,
    }
}

/// Convenience: random prompts for the proxy.
pub fn proxy_prompts(vocab: usize, n: usize, len: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.below(vocab) as u32).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        let mut c = ModelConfig::tiny();
        c.n_layers = 2;
        c.vocab = 64;
        c.d_model = 32;
        c.n_heads = 4;
        c.n_kv_heads = 2;
        c.head_dim = 16;
        c.ffn = 48;
        c.group = 8;
        c.resid = 16;
        c
    }

    #[test]
    fn fp_codec_agrees_perfectly() {
        let c = cfg();
        let prompts = proxy_prompts(c.vocab, 2, 12, 1);
        let s = decode_agreement(&c, 3, 6.0, &QuantSpec::Fp16, &prompts, 6);
        assert!((s.agreement - 1.0).abs() < 1e-12, "{s:?}");
        assert!(s.logit_cos > 0.999999);
    }

    #[test]
    fn polar_beats_int_under_outliers() {
        let c = cfg();
        let prompts = proxy_prompts(c.vocab, 3, 24, 2);
        let polar = decode_agreement(
            &c, 9, 14.0,
            &QuantSpec::Polar { r_bits: 4, t_bits: 4, group: 8 },
            &prompts, 8,
        );
        let int4 = decode_agreement(&c, 9, 14.0, &QuantSpec::Int { bits: 4 }, &prompts, 8);
        assert!(
            polar.logit_cos > int4.logit_cos,
            "polar {} vs int {}",
            polar.logit_cos,
            int4.logit_cos
        );
    }
}
