//! Fixed-width table printer — renders bench output in the paper's row
//! formats (EXPERIMENTS.md records these verbatim).

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let line = |cells: &[String], w: &[usize]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &w));
        let mut sep = String::from("|");
        for width in &w {
            sep.push_str(&format!("{}|", "-".repeat(width + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row, &w));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a score with its delta vs a baseline, paper-style:
/// `36.41 (-2.47)`.
pub fn score_with_delta(score: f64, baseline: f64) -> String {
    let d = score - baseline;
    let sign = if d >= 0.0 { "+" } else { "" };
    format!("{score:.2} ({sign}{d:.2})")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Scientific-ish compact float for error metrics.
pub fn sci(x: f64) -> String {
    if x.is_nan() {
        "N.A".into()
    } else if x == 0.0 {
        "0".into()
    } else if x.abs() < 1e-3 || x.abs() >= 1e4 {
        format!("{x:.2e}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["method", "score"]);
        t.row(vec!["PolarQuant44".into(), "49.39".into()]);
        t.row(vec!["KIVI-4".into(), "49.36".into()]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("| PolarQuant44 |"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len(), "alignment");
    }

    #[test]
    fn delta_formatting() {
        assert_eq!(score_with_delta(36.41, 38.88), "36.41 (-2.47)");
        assert_eq!(score_with_delta(49.53, 49.26), "49.53 (+0.27)");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }
}
