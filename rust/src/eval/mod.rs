//! Evaluation harness: the metrics + proxies behind every reproduced
//! table (DESIGN.md §3 documents why these stand in for LongBench/GSM8K/
//! AIME — no datasets or checkpoints exist offline; the proxies measure
//! the same axis the paper varies: quantization fidelity under key-cache
//! channel outliers).
//!
//! * [`fidelity`] — codec-level: key reconstruction error + attention-
//!   distribution fidelity on profile-structured activations
//! * [`proxy`] — model-level: greedy-decode agreement + logit cosine of a
//!   codec-quantized model against its own fp twin (teacher-forced)
//! * [`tables`] — fixed-width printers that render rows in the paper's
//!   table formats

pub mod fidelity;
pub mod proxy;
pub mod tables;

pub use fidelity::{eval_codec, Fidelity};
pub use proxy::{decode_agreement, ProxyScore};
pub use tables::Table;
