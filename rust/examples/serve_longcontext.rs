//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real serving workload:
//!   L1 Pallas kernels -> L2 JAX decode/prefill graphs -> HLO text ->
//!   PJRT CPU client -> L3 Rust engine (router, dynamic batcher,
//!   quantized paged cache) -> TCP server -> load-generating clients.
//!
//! Fires a Poisson arrival trace of mixed-length prompts at a 2-worker
//! server and reports throughput, latency percentiles, and cache memory.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_longcontext
//! # flags: --requests N --rps R --gen-len G --workers W --backend native|pjrt
//! ```

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use polarquant::coordinator::{Engine, EngineOpts};
use polarquant::server::{serve, Client};
use polarquant::util::rng::Rng;
use polarquant::util::stats::percentile;
use polarquant::workload::ArrivalTrace;

fn flag(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn flag_s(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let n_requests = flag("--requests", 24);
    let rps = flag("--rps", 8) as f64;
    let gen_len = flag("--gen-len", 24);
    let workers = flag("--workers", 2);
    let backend = flag_s("--backend", "pjrt");

    let dir = PathBuf::from("artifacts");
    let have_artifacts = dir.join("manifest.json").exists();
    let use_pjrt = backend == "pjrt" && have_artifacts;
    if backend == "pjrt" && !have_artifacts {
        eprintln!("no artifacts/ — falling back to native backend (run `make artifacts`)");
    }
    println!(
        "== serve_longcontext: {} requests @ {:.1} rps, gen {}, {} workers, backend {} ==",
        n_requests,
        rps,
        gen_len,
        workers,
        if use_pjrt { "pjrt" } else { "native" }
    );

    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let dir = PathBuf::from("artifacts");
        if use_pjrt {
            Engine::pjrt_from_artifacts(&dir, EngineOpts::default()).expect("pjrt engine")
        } else if dir.join("manifest.json").exists() {
            Engine::native_from_artifacts(&dir, EngineOpts::default()).expect("native engine")
        } else {
            Engine::native_synthetic(
                polarquant::model::ModelConfig::tiny(),
                w as u64,
                6.0,
                EngineOpts::default(),
            )
        }
    });
    let handle = serve(factory, "127.0.0.1:0", workers)?;
    println!("server on {}", handle.addr);

    // Poisson arrivals, mixed prompt lengths (longest must fit the largest
    // prefill bucket: 256 for the tiny artifact set)
    let mut rng = Rng::new(12345);
    let trace = ArrivalTrace::poisson(&mut rng, n_requests, rps);
    let t0 = Instant::now();
    let results: Arc<Mutex<Vec<(f64, f64, usize)>>> = Arc::new(Mutex::new(Vec::new()));
    let mut threads = Vec::new();
    for (i, &offset) in trace.offsets.iter().enumerate() {
        let addr = handle.addr.clone();
        let results = results.clone();
        let plen = 16 + (i * 37) % 180; // 16..196 tokens
        let session = (i % 6) as u64;
        threads.push(std::thread::spawn(move || {
            let now = t0.elapsed().as_secs_f64();
            if offset > now {
                std::thread::sleep(Duration::from_secs_f64(offset - now));
            }
            let mut client = Client::connect(&addr).expect("connect");
            let prompt: Vec<u32> = (0..plen as u32).map(|t| (t * 13 + i as u32) % 512).collect();
            let sent = Instant::now();
            let reply = client.generate(&prompt, gen_len, Some(session)).expect("generate");
            let wall = sent.elapsed().as_secs_f64();
            assert_eq!(reply.tokens.len(), gen_len, "request {i} truncated");
            results.lock().unwrap().push((reply.ttft_ms, wall * 1e3, reply.tokens.len()));
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let total_s = t0.elapsed().as_secs_f64();
    let results = results.lock().unwrap();

    let ttfts: Vec<f64> = results.iter().map(|r| r.0).collect();
    let walls: Vec<f64> = results.iter().map(|r| r.1).collect();
    let tokens: usize = results.iter().map(|r| r.2).sum();
    println!("\n== results ==");
    println!("completed        : {}/{} requests in {:.2}s", results.len(), n_requests, total_s);
    println!("decode throughput: {:.1} tok/s (aggregate)", tokens as f64 / total_s);
    println!(
        "ttft             : p50 {:.1}ms  p95 {:.1}ms  max {:.1}ms",
        percentile(&ttfts, 50.0),
        percentile(&ttfts, 95.0),
        percentile(&ttfts, 100.0)
    );
    println!(
        "request latency  : p50 {:.1}ms  p95 {:.1}ms",
        percentile(&walls, 50.0),
        percentile(&walls, 95.0)
    );
    handle.stop();
    println!("\nall layers composed: Pallas kernels -> JAX graphs -> HLO text -> PJRT -> engine -> server OK");
    Ok(())
}
