//! Quant explorer: the paper's Figures 1–2 as numbers.
//!
//! For each model profile, shows (a) the channel-outlier structure of
//! post-RoPE keys, (b) how the polar transformation regularizes it
//! (radius/angle spread per pair vs Cartesian spread per channel), and
//! (c) the resulting fidelity of every codec at 4-bit and ~3-bit budgets.
//!
//! ```bash
//! cargo run --release --example quant_explorer
//! ```

use polarquant::eval::{eval_codec, Table};
use polarquant::quant::QuantSpec;
use polarquant::util::rng::Rng;
use polarquant::workload::PROFILES;

fn main() {
    let d = 128;
    let tokens = 512;
    let group = 128;

    for profile in &PROFILES {
        let mut rng = Rng::new(42);
        let k = profile.keys(&mut rng, tokens, d, 10000.0);

        // --- Figure 1(a): channel magnitude spread -----------------------
        let mut chan_mag = vec![0.0f32; d];
        for n in 0..tokens {
            for j in 0..d {
                chan_mag[j] += k[n * d + j].abs() / tokens as f32;
            }
        }
        let max_mag = chan_mag.iter().cloned().fold(0.0f32, f32::max);
        let med = {
            let mut m = chan_mag.clone();
            m.sort_by(|a, b| a.partial_cmp(b).unwrap());
            m[d / 2]
        };

        // --- Figure 1(b): polar regularity of the strongest pair ---------
        let (mut best_j, mut best_m) = (0usize, 0.0f32);
        for j in 0..d / 2 {
            let m: f32 = (0..tokens)
                .map(|n| {
                    let x = k[n * d + 2 * j];
                    let y = k[n * d + 2 * j + 1];
                    (x * x + y * y).sqrt()
                })
                .sum::<f32>()
                / tokens as f32;
            if m > best_m {
                best_m = m;
                best_j = j;
            }
        }
        let radii: Vec<f32> = (0..tokens)
            .map(|n| {
                let x = k[n * d + 2 * best_j];
                let y = k[n * d + 2 * best_j + 1];
                (x * x + y * y).sqrt()
            })
            .collect();
        let rmean = radii.iter().sum::<f32>() / tokens as f32;
        let rstd = (radii.iter().map(|r| (r - rmean) * (r - rmean)).sum::<f32>()
            / tokens as f32)
            .sqrt();
        let xs: Vec<f32> = (0..tokens).map(|n| k[n * d + 2 * best_j]).collect();
        let xmean = xs.iter().sum::<f32>() / tokens as f32;
        let xstd =
            (xs.iter().map(|x| (x - xmean) * (x - xmean)).sum::<f32>() / tokens as f32).sqrt();

        println!("=== profile {} ===", profile.name);
        println!(
            "Fig 1a | channel |mean| spread: max {:.2} vs median {:.3}  ({:.0}x outlier)",
            max_mag,
            med,
            max_mag / med.max(1e-6)
        );
        println!(
            "Fig 1b | strongest pair #{best_j}: radius std/mean = {:.3} (ring!)  vs  \
             Cartesian x std = {:.2} (outlier axis)",
            rstd / rmean.max(1e-6),
            xstd
        );
        println!(
            "Fig 2  | quantization range: radius {:.2} vs x-axis {:.2} — the polar\n\
             \x20      range is {:.1}x narrower, so the same bits quantize finer",
            radii.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
                - radii.iter().cloned().fold(f32::INFINITY, f32::min),
            xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
                - xs.iter().cloned().fold(f32::INFINITY, f32::min),
            (xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
                - xs.iter().cloned().fold(f32::INFINITY, f32::min))
                / (radii.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
                    - radii.iter().cloned().fold(f32::INFINITY, f32::min)).max(1e-6)
        );

        // --- codec fidelity table ----------------------------------------
        let mut t = Table::new(
            &format!("codec fidelity — {} (d={d}, T={tokens})", profile.name),
            &["method", "bits", "key MSE", "attn KL", "top8 overlap"],
        );
        for spec in [
            QuantSpec::Polar { r_bits: 4, t_bits: 4, group },
            QuantSpec::Kivi { bits: 4, group },
            QuantSpec::Int { bits: 4 },
            QuantSpec::Zip { bits: 4 },
            QuantSpec::Polar { r_bits: 3, t_bits: 3, group },
            QuantSpec::Kivi { bits: 2, group: 32 },
            QuantSpec::Qjl { bits_per_channel: 3 },
        ] {
            let f = eval_codec(&spec, profile, d, tokens, 16, 7);
            t.row(vec![
                spec.label(),
                format!("{:.2}", f.bits),
                polarquant::eval::tables::sci(f.key_mse),
                polarquant::eval::tables::sci(f.attn_kl),
                format!("{:.3}", f.top8_overlap),
            ]);
        }
        t.print();
        println!();
    }
}
