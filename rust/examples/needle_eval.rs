//! Needle-retrieval eviction study (Table 8's workload, expanded):
//! SnapKV prompt compression on top of the PolarQuant cache, sweeping the
//! budget and reporting generation agreement vs the full cache — plus the
//! memory saved.
//!
//! ```bash
//! cargo run --release --example needle_eval
//! ```

use polarquant::coordinator::engine::SnapKvOpts;
use polarquant::coordinator::{Engine, EngineOpts};
use polarquant::eval::Table;
use polarquant::model::ModelConfig;
use polarquant::workload::{PromptKind, RequestGen};

fn cfg() -> ModelConfig {
    let mut c = ModelConfig::tiny();
    c.n_layers = 2;
    c.vocab = 128;
    c.d_model = 64;
    c.n_heads = 4;
    c.n_kv_heads = 2;
    c.head_dim = 32;
    c.ffn = 96;
    c.group = 8;
    c.resid = 16;
    c
}

fn run(snapkv: Option<SnapKvOpts>, n_req: usize, prompt_len: usize, gen_len: usize)
    -> (Vec<Vec<u32>>, usize)
{
    let mut opts = EngineOpts::default();
    opts.snapkv = snapkv;
    let mut eng = Engine::native_synthetic(cfg(), 80, 6.0, opts);
    let mut gen = RequestGen::new(128, 81);
    for _ in 0..n_req {
        let req = gen.request(PromptKind::Needle { len: prompt_len, needle: 111 }, gen_len);
        eng.submit(req).unwrap();
    }
    let mut peak = 0usize;
    let mut done = Vec::new();
    while !eng.idle() {
        done.extend(eng.step().unwrap());
        peak = peak.max(eng.cache_report().bytes);
    }
    done.sort_by_key(|c| c.id);
    (done.into_iter().map(|c| c.tokens).collect(), peak)
}

fn main() {
    let prompt_len = 96;
    let gen_len = 16;
    let n_req = 8;
    println!(
        "needle retrieval: {n_req} prompts of {prompt_len} tokens (one needle each), \
         {gen_len}-token greedy generations\n"
    );
    let (full, full_mem) = run(None, n_req, prompt_len, gen_len);
    let mut t = Table::new(
        "SnapKV x PolarQuant sweep (agreement with full-cache generation)",
        &["budget", "window", "agreement %", "peak cache KB", "memory vs full"],
    );
    t.row(vec![
        "full".into(),
        "-".into(),
        "100.0".into(),
        format!("{:.1}", full_mem as f64 / 1024.0),
        "1.00x".into(),
    ]);
    for (budget, window) in [(64usize, 16usize), (48, 16), (32, 8), (16, 8)] {
        let (snap, mem) = run(Some(SnapKvOpts { budget, window }), n_req, prompt_len, gen_len);
        let mut agree = 0;
        let mut total = 0;
        for (a, b) in full.iter().zip(&snap) {
            for (x, y) in a.iter().zip(b) {
                agree += (x == y) as usize;
                total += 1;
            }
        }
        t.row(vec![
            budget.to_string(),
            window.to_string(),
            format!("{:.1}", 100.0 * agree as f64 / total as f64),
            format!("{:.1}", mem as f64 / 1024.0),
            format!("{:.2}x", mem as f64 / full_mem as f64),
        ]);
    }
    t.print();
    println!("\nshape (paper Table 8): agreement decays gracefully with budget while");
    println!("memory shrinks — SnapKV composes with PolarQuant without collapse.");
}
