//! Quickstart: build an engine, submit requests, read completions.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Uses the PJRT backend (AOT HLO graphs on the CPU PJRT client) when
//! `artifacts/` exists, else falls back to a synthetic native model so the
//! example always runs.

use std::path::PathBuf;

use polarquant::coordinator::{Engine, EngineOpts, Request};
use polarquant::model::ModelConfig;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from("artifacts");
    let mut engine = if dir.join("manifest.json").exists() {
        println!("backend: PJRT (AOT artifacts from {dir:?})");
        Engine::pjrt_from_artifacts(&dir, EngineOpts::default())?
    } else {
        println!("backend: native synthetic (run `make artifacts` for the PJRT path)");
        Engine::native_synthetic(ModelConfig::tiny(), 0, 6.0, EngineOpts::default())
    };

    // a few greedy generation requests with mixed prompt lengths
    for (i, plen) in [12usize, 40, 80].iter().enumerate() {
        let prompt: Vec<u32> = (0..*plen as u32).map(|t| (t * 17 + 3) % 512).collect();
        engine.submit(Request::greedy(i as u64 + 1, prompt, 16)).unwrap();
    }

    let completions = engine.run_to_completion()?;
    for c in &completions {
        println!(
            "request {}: prompt {:>3} tokens -> {:?}... (ttft {:.1}ms, total {:.1}ms)",
            c.id,
            c.prompt_len,
            &c.tokens[..c.tokens.len().min(8)],
            c.ttft_s.unwrap_or(0.0) * 1e3,
            c.total_s.unwrap_or(0.0) * 1e3,
        );
    }
    println!("\nengine metrics: {}", engine.metrics.summary());
    println!("cache at exit : {:?}", engine.cache_report());
    Ok(())
}
