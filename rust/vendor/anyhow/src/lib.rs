//! Offline drop-in subset of [`anyhow`](https://docs.rs/anyhow)'s API.
//!
//! The serving image has no crates.io access, so this path dependency
//! provides exactly the surface the workspace uses: [`Error`], [`Result`],
//! the [`Context`] extension trait, and the `anyhow!` / `bail!` /
//! `ensure!` macros.
//! Error values carry a chain of context strings (outermost first); `{}`
//! prints the outermost message, `{:#}` prints the full `a: b: c` chain,
//! `{:?}` prints the anyhow-style multi-line report.

use std::fmt;

/// A context-carrying error. Deliberately does NOT implement
/// `std::error::Error` so the blanket `From` below stays coherent —
/// the same trick the real anyhow uses.
pub struct Error {
    /// context chain, outermost message first
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost message (the deepest cause), as in real anyhow.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // flatten the source chain into context strings
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(..))` unless the condition holds —
/// the validation workhorse of the tier codec's untrusted-input paths.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_err().context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"));
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn option_context_and_macros() {
        let n: Option<u32> = None;
        assert!(n.context("missing").is_err());
        let e = anyhow!("bad {}", 7);
        assert_eq!(format!("{e}"), "bad 7");
        fn bails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{:#}", bails().unwrap_err()), "nope 1");
    }

    #[test]
    fn ensure_returns_early_only_on_false() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(format!("{:#}", check(12).unwrap_err()), "x too big: 12");
        assert!(format!("{:#}", check(5).unwrap_err()).contains("x != 5"));
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<(), Error> = io_err().with_context(|| format!("step {}", 3));
        assert_eq!(format!("{:#}", r.unwrap_err()), "step 3: gone");
    }
}
