//! Decode-batch throughput: the batched LUT decode path vs the KIVI
//! dequant-then-dot baseline at serving batch sizes {1, 8, 32, 128}, plus
//! the engine-level thread-parallel decode pool.  Emits
//! `BENCH_decode_batch.json` (override the path with `BENCH_OUT`) so CI
//! can accumulate the perf trajectory.
//!
//! Kernel section: one kv-head stream per sequence, Llama-3.1-8B attention
//! geometry (d=128, 4 query heads per kv head, group=128), PolarQuant
//! r4/t4 vs KIVI-4 at the SAME group size — the ISSUE-1 acceptance
//! comparison.  "Tokens/s" counts one decode step per sequence per
//! iteration (B tokens of QK work over the full cached context).
//!
//! Roofline section: the ScoreKernel implementations (scalar vs SIMD,
//! when available) head-to-head on identical staged pack-v2 lanes, in
//! scores/s and packed-bytes GB/s, vs the KIVI dequant baseline.
//!
//! Engine section: end-to-end decode tokens/s of the native engine with
//! the fixed decode pool on vs off, same request mix.
//!
//! Chunked-prefill section: worst-case decode stall (max engine-step
//! wall time) while long prompts arrive mid-decode, chunking off vs on
//! (`--prefill-chunk N`, default 16) — the head-of-line-blocking probe
//! CI tracks per commit.
//!
//! Tier section: shared-prefix requests served cold (full re-prefill),
//! resident (RAM prefix hit), and demoted-then-promoted (pages faulted
//! back from the disk tier) — promotion latency, tier hit counts, and
//! peak resident bytes per mode.
//!
//! Streaming section: client-visible time-to-first-output and
//! inter-token latency, one-shot vs streaming API over the same request
//! mix — the latency visibility the streaming session API adds.
//!
//! Multi-tenant section: a well-behaved tenant's ITL while a flooding
//! tenant saturates the engine — solo baseline vs FCFS vs `--sched wfq`
//! (weight 4:1).  The acceptance bar (well-behaved p99 ITL under flood
//! within 25% of its solo baseline under WFQ) is recorded per run as
//! `wfq_within_25pct`.
//!
//! Speculative section: greedy decoding with self-drafted windows on the
//! truncated code plane (`--speculate K`) vs the k=0 baseline — output
//! asserted bit-identical, wins reported as decode-steps-per-token,
//! accepted-run-length, and TTFT/ITL deltas at k in {2, 4} on both the
//! halved default draft and the exact-width (always-accept) draft.

use std::time::Instant;

use polarquant::coordinator::{Engine, EngineOpts, Event, Request, SchedMode, TenancyOpts, TierOpts};
use polarquant::model::ModelConfig;
use polarquant::quant::kivi::{self, KiviQk, KiviSpec};
use polarquant::quant::polar::{self, PolarEncoded, PolarSpec};
use polarquant::quant::{select_kernel, KernelKind, QkLut, SeqScoreJob};
use polarquant::util::bench::{bench_fn, black_box, BenchOpts};
use polarquant::util::json::{self, num, obj, Value};
use polarquant::util::rng::Rng;
use polarquant::util::stats::percentile;

const D: usize = 128;
const HQ: usize = 4; // query heads per kv head (32/8)
const GROUP: usize = 128;
const BATCHES: [usize; 4] = [1, 8, 32, 128];

struct SeqData {
    polar: PolarEncoded,
    kivi: kivi::KiviEncoded,
    qs: Vec<Vec<f32>>,
}

fn build_seqs(n: usize, ctx: usize, seed: u64) -> Vec<SeqData> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let keys = rng.normal_vec(ctx * D);
            SeqData {
                polar: polar::encode(&keys, D, &PolarSpec::new(4, 4, GROUP)),
                kivi: kivi::encode(&keys, D, &KiviSpec::new(4, GROUP)),
                qs: (0..HQ).map(|_| rng.normal_vec(D)).collect(),
            }
        })
        .collect()
}

/// Pre-timing sanity: both paths score the same dequantized geometry, so
/// a LUT "win" can't come from computing something cheaper-but-wrong.
fn sanity_check(seqs: &[SeqData], ctx: usize) {
    let s = &seqs[0];
    let mut lut = QkLut::new(PolarSpec::new(4, 4, GROUP), D, HQ);
    let mut p_scores = Vec::new();
    lut.scores(&s.qs[0], &s.polar, &mut p_scores);
    let k_hat = polar::decode(&s.polar, D);
    for n in (0..ctx).step_by(ctx / 7 + 1) {
        let want = polarquant::tensor::ops::dot(&s.qs[0], &k_hat[n * D..(n + 1) * D]);
        assert!(
            (p_scores[n] - want).abs() < 2e-3 * (1.0 + want.abs()),
            "lut score diverges from dequant-dot at n={n}: {} vs {want}",
            p_scores[n]
        );
    }
}

fn kernel_section(ctx: usize, opts: BenchOpts) -> Vec<Value> {
    let all = build_seqs(*BATCHES.iter().max().unwrap(), ctx, 7);
    sanity_check(&all, ctx);
    let mut rows = Vec::new();
    println!("# kernel: batched LUT (scores_batch) vs KIVI-4 dequant-then-dot");
    println!("# d={D}, {HQ} q-heads/kv-head, group={GROUP}, ctx={ctx}\n");
    for &b in &BATCHES {
        let seqs = &all[..b];
        let qrefs: Vec<Vec<&[f32]>> = seqs
            .iter()
            .map(|s| s.qs.iter().map(|q| q.as_slice()).collect())
            .collect();
        let jobs: Vec<SeqScoreJob> = seqs
            .iter()
            .zip(&qrefs)
            .map(|(s, q)| SeqScoreJob { qs: q, groups: &s.polar.groups })
            .collect();

        let mut lut = QkLut::new(PolarSpec::new(4, 4, GROUP), D, HQ);
        let mut out: Vec<Vec<Vec<f32>>> =
            seqs.iter().map(|_| vec![Vec::with_capacity(ctx); HQ]).collect();
        let r_polar = bench_fn(&format!("polar44 scores_batch  b={b}"), opts, || {
            lut.scores_batch(&jobs, &mut out);
            black_box(out[b - 1][HQ - 1][ctx - 1])
        });
        println!("{r_polar}");

        let mut qk = KiviQk::new(KiviSpec::new(4, GROUP), D);
        let mut kout = Vec::with_capacity(ctx);
        let r_kivi = bench_fn(&format!("kivi4 dequant-dot     b={b}"), opts, || {
            let mut acc = 0.0f32;
            for (s, q) in seqs.iter().zip(&qrefs) {
                for qh in q {
                    qk.scores(qh, &s.kivi, &mut kout);
                    acc += kout[ctx - 1];
                }
            }
            black_box(acc)
        });
        println!("{r_kivi}");

        let speedup = r_kivi.mean_s / r_polar.mean_s;
        // ISSUE-1 acceptance: the batched LUT path must beat the KIVI
        // dequant-then-dot baseline at batch >= 8 — recorded in the JSON
        // so CI artifacts carry the verdict, not just raw numbers
        let beats = speedup > 1.0;
        let verdict = if b >= 8 && !beats { "FAIL" } else { "ok" };
        println!("  -> polar {speedup:.2}x vs kivi [{verdict}]\n");
        rows.push(obj(vec![
            ("batch", num(b as f64)),
            ("polar_mean_s", num(r_polar.mean_s)),
            ("kivi_mean_s", num(r_kivi.mean_s)),
            ("polar_tok_s", num(b as f64 / r_polar.mean_s)),
            ("kivi_tok_s", num(b as f64 / r_kivi.mean_s)),
            ("speedup_vs_kivi", num(speedup)),
            ("lut_beats_kivi", Value::Bool(beats)),
        ]));
    }
    rows
}

/// Roofline section: the ScoreKernel implementations head-to-head on the
/// SAME staged pack-v2 lanes — scalar vs SIMD (when the build carries the
/// `simd` feature and the CPU has AVX2) vs the KIVI dequant-then-dot
/// baseline.  "scores/s" counts one (query-head, cached-token) score per
/// unit; "GB/s" charges the packed quantized key bytes walked per pass (a
/// traffic lower bound — f32 staging-scratch re-reads are not charged),
/// so the two axes bracket the roofline.  The acceptance bar — SIMD >= 2x
/// scalar scores/s at batch >= 8 — is recorded per row as `simd_ge_2x`;
/// when the gap falls short the committed JSON documents the hardware cap
/// instead of hiding it.
fn roofline_section(ctx: usize, opts: BenchOpts) -> Vec<Value> {
    let all = build_seqs(*BATCHES.iter().max().unwrap(), ctx, 29);
    let simd = select_kernel(KernelKind::Simd);
    let mut rows = Vec::new();
    println!("# roofline: ScoreKernel scalar vs simd vs kivi-4 dequant baseline");
    match &simd {
        Ok(k) => println!("# --kernel simd resolves to '{}'", k.name()),
        Err(e) => println!("# simd kernel unavailable in this build/CPU: {e}"),
    }
    println!("# d={D}, {HQ} q-heads/kv-head, group={GROUP}, ctx={ctx}\n");
    for &b in &BATCHES {
        let seqs = &all[..b];
        let qrefs: Vec<Vec<&[f32]>> = seqs
            .iter()
            .map(|s| s.qs.iter().map(|q| q.as_slice()).collect())
            .collect();
        let jobs: Vec<SeqScoreJob> = seqs
            .iter()
            .zip(&qrefs)
            .map(|(s, q)| SeqScoreJob { qs: q, groups: &s.polar.groups })
            .collect();
        // packed key bytes one scoring pass walks (codes + group params)
        let pass_bytes: usize = seqs
            .iter()
            .map(|s| s.polar.groups.iter().map(|g| g.nbytes()).sum::<usize>())
            .sum();
        let pass_scores = (b * HQ * ctx) as f64;
        let gb = |mean_s: f64| pass_bytes as f64 / mean_s / 1e9;

        let mut time_kernel = |name: &str, kernel| {
            let mut lut = QkLut::with_kernel(PolarSpec::new(4, 4, GROUP), D, HQ, kernel);
            let mut out: Vec<Vec<Vec<f32>>> =
                seqs.iter().map(|_| vec![Vec::with_capacity(ctx); HQ]).collect();
            let r = bench_fn(&format!("{name:<6} kernel b={b}"), opts, || {
                lut.scores_batch(&jobs, &mut out);
                black_box(out[b - 1][HQ - 1][ctx - 1])
            });
            println!("{r}   ({:.2} Mscores/s, {:.3} GB/s)", pass_scores / r.mean_s / 1e6, gb(r.mean_s));
            r
        };
        let r_scalar = time_kernel("scalar", select_kernel(KernelKind::Scalar).unwrap());

        let mut qk = KiviQk::new(KiviSpec::new(4, GROUP), D);
        let mut kout = Vec::with_capacity(ctx);
        let r_kivi = bench_fn(&format!("kivi4  dequant b={b}"), opts, || {
            let mut acc = 0.0f32;
            for (s, q) in seqs.iter().zip(&qrefs) {
                for qh in q {
                    qk.scores(qh, &s.kivi, &mut kout);
                    acc += kout[ctx - 1];
                }
            }
            black_box(acc)
        });
        println!("{r_kivi}   ({:.2} Mscores/s)", pass_scores / r_kivi.mean_s / 1e6);

        let mut fields = vec![
            ("batch", num(b as f64)),
            ("pass_bytes", num(pass_bytes as f64)),
            ("scalar_mean_s", num(r_scalar.mean_s)),
            ("scalar_scores_s", num(pass_scores / r_scalar.mean_s)),
            ("scalar_gb_s", num(gb(r_scalar.mean_s))),
            ("kivi_mean_s", num(r_kivi.mean_s)),
            ("kivi_scores_s", num(pass_scores / r_kivi.mean_s)),
        ];
        match &simd {
            Ok(k) => {
                let r_simd = time_kernel("simd", *k);
                let speedup = r_scalar.mean_s / r_simd.mean_s;
                let ge_2x = speedup >= 2.0;
                let verdict = if b >= 8 && !ge_2x { "below 2x bar" } else { "ok" };
                println!("  -> simd {speedup:.2}x vs scalar [{verdict}]\n");
                fields.push(("simd_mean_s", num(r_simd.mean_s)));
                fields.push(("simd_scores_s", num(pass_scores / r_simd.mean_s)));
                fields.push(("simd_gb_s", num(gb(r_simd.mean_s))));
                fields.push(("simd_speedup_vs_scalar", num(speedup)));
                fields.push(("simd_ge_2x", Value::Bool(ge_2x)));
            }
            Err(e) => {
                println!("  -> simd skipped: {e}\n");
                fields.push(("simd", json::s(&format!("unavailable: {e}"))));
            }
        }
        rows.push(obj(fields));
    }
    rows
}

fn engine_cfg() -> ModelConfig {
    let mut c = ModelConfig::tiny();
    c.n_layers = 2;
    c.vocab = 128;
    c.d_model = 64;
    c.n_heads = 4;
    c.n_kv_heads = 2;
    c.head_dim = 32;
    c.ffn = 96;
    c.group = 16;
    c.resid = 32;
    c
}

fn engine_run(batch: usize, workers: usize, prompt_len: usize, gen_len: usize) -> f64 {
    let mut opts = EngineOpts::default();
    opts.decode_workers = workers;
    opts.policy.max_running = batch.max(32);
    // admit the whole batch on the first step so prefill (serial on the
    // engine thread in both configs) stays outside the timed region
    opts.policy.prefill_per_step = batch;
    opts.admission.max_queue = batch.max(256);
    let mut eng = Engine::native_synthetic(engine_cfg(), 3, 6.0, opts);
    let mut rng = Rng::new(11);
    for i in 0..batch {
        let prompt: Vec<u32> = (0..prompt_len).map(|_| rng.below(128) as u32).collect();
        eng.submit(Request::greedy(i as u64, prompt, gen_len)).unwrap();
    }
    eng.step().unwrap(); // all prefills + one decode iteration, untimed
    let tok0 = eng.metrics.decode_tokens;
    let t0 = std::time::Instant::now();
    eng.run_to_completion().unwrap();
    // pure decode throughput over the timed region
    (eng.metrics.decode_tokens - tok0) as f64 / t0.elapsed().as_secs_f64()
}

/// Head-of-line blocking probe: a batch of sequences decodes while long
/// prompts keep arriving.  Returns (decode tok/s, worst step wall ms,
/// prefill chunks run) — with `chunk == 0` the worst step contains a
/// whole-prompt inline prefill, the head-of-line blocking chunked
/// prefill removes.
fn chunked_run(chunk: usize, decoders: usize, prompt_len: usize) -> (f64, f64, u64) {
    let mut opts = EngineOpts::default();
    opts.prefill_chunk = chunk;
    opts.policy.max_running = 64;
    opts.admission.max_queue = 256;
    let mut eng = Engine::native_synthetic(engine_cfg(), 5, 6.0, opts);
    let mut rng = Rng::new(13);
    // warm pool of decoders with short prompts and long generations
    for i in 0..decoders {
        let prompt: Vec<u32> = (0..8).map(|_| rng.below(128) as u32).collect();
        eng.submit(Request::greedy(i as u64, prompt, 64)).unwrap();
    }
    while eng.metrics.requests_finished == 0 && eng.running() < decoders {
        eng.step().unwrap();
    }
    // long prompts arrive while the pool decodes; one engine step is the
    // longest a decoding sequence waits for its next token, so step wall
    // time IS the decode stall — directly comparable across modes (the
    // chunked engine additionally records its own decode_stall hist)
    let tok0 = eng.metrics.decode_tokens; // exclude warm-up tokens
    let t0 = std::time::Instant::now();
    for i in 0..4 {
        let prompt: Vec<u32> = (0..prompt_len).map(|_| rng.below(128) as u32).collect();
        eng.submit(Request::greedy(1000 + i as u64, prompt, 8)).unwrap();
    }
    let mut step_ms: Vec<f64> = Vec::new();
    while !eng.idle() {
        let s = std::time::Instant::now();
        eng.step().unwrap();
        step_ms.push(s.elapsed().as_secs_f64() * 1e3);
    }
    let tok_s = (eng.metrics.decode_tokens - tok0) as f64 / t0.elapsed().as_secs_f64();
    // WORST step is the signal: with chunk=0 only a couple of steps carry
    // the inline prefills, so a p95 over all steps would never see them —
    // max is the head-of-line blocking bound a decoder actually observes
    let stall_max_ms = step_ms.iter().cloned().fold(0.0f64, f64::max);
    (tok_s, stall_max_ms, eng.metrics.prefill_chunks)
}

fn chunked_section(quick: bool, chunk: usize) -> Vec<Value> {
    let (prompt_len, decoders) = if quick { (128, 8) } else { (512, 16) };
    let mut rows = Vec::new();
    println!("# chunked prefill: decode stall while {decoders} sequences decode");
    println!("# long prompts of {prompt_len} tokens arrive mid-decode\n");
    for &c in &[0usize, chunk] {
        let (tok_s, stall_max_ms, chunks) = chunked_run(c, decoders, prompt_len);
        println!(
            "prefill_chunk {c:>4}: {tok_s:>9.1} tok/s   worst stall {stall_max_ms:>8.3} ms   ({chunks} chunks)"
        );
        rows.push(obj(vec![
            ("prefill_chunk", num(c as f64)),
            ("prompt_len", num(prompt_len as f64)),
            ("decoders", num(decoders as f64)),
            ("decode_tok_s", num(tok_s)),
            ("decode_stall_max_ms", num(stall_max_ms)),
            ("prefill_chunks", num(chunks as f64)),
        ]));
    }
    println!();
    rows
}

/// Prefix-reuse probe: N requests sharing one long "system prompt"
/// served by a prefix-caching engine vs a cold one.  Reports prefill
/// tokens actually run (vs reused), logical vs physical cache bytes while
/// the batch decodes (the refcount-sharing savings), and decode-step
/// latency — the paged-cache acceptance numbers CI tracks per commit.
fn prefix_run(prefix: bool, sharers: usize, prefix_len: usize) -> Value {
    let mut opts = EngineOpts::default();
    opts.prefill_chunk = 32; // multiple of engine_cfg group=16
    opts.prefill_quantize_eagerly = true; // identical math in both modes
    opts.prefix_cache = prefix;
    opts.policy.max_running = 64;
    opts.policy.prefill_per_step = 1; // serialized prefills: stable chunk
    opts.admission.max_queue = 256;
    let mut eng = Engine::native_synthetic(engine_cfg(), 7, 6.0, opts);
    let mut rng = Rng::new(17);
    let system: Vec<u32> = (0..prefix_len).map(|_| rng.below(128) as u32).collect();
    // warm request registers the shared prefix (also timed for cold)
    eng.submit(Request::greedy(0, system.clone(), 4)).unwrap();
    eng.run_to_completion().unwrap();
    let prefill0 = eng.metrics.prefill_tokens;
    let t0 = std::time::Instant::now();
    for i in 0..sharers {
        let prompt: Vec<u32> = system
            .iter()
            .cloned()
            .chain((0..8).map(|_| rng.below(128) as u32))
            .collect();
        eng.submit(Request::greedy(1 + i as u64, prompt, 16)).unwrap();
    }
    // drain the batch, tracking peak residency both ways: shared pages
    // are resident once physically however many sequences reference them
    let (mut peak_logical, mut peak_physical) = (0usize, 0usize);
    while !eng.idle() {
        eng.step().unwrap();
        let r = eng.cache_report();
        peak_logical = peak_logical.max(r.bytes);
        peak_physical = peak_physical.max(r.physical_bytes);
    }
    let wall = t0.elapsed().as_secs_f64();
    let prefill_ran = eng.metrics.prefill_tokens - prefill0;
    let label = if prefix { "prefix on " } else { "prefix off" };
    println!(
        "{label}: prefill {prefill_ran:>6} tok (reused {:>6}), peak bytes {:>9} logical / {:>9} physical, tok p50 {:>7.3} ms, {wall:.3}s",
        eng.metrics.prefix_tokens_reused,
        peak_logical,
        peak_physical,
        eng.metrics.per_token.p(50.0) * 1e3,
    );
    obj(vec![
        ("prefix_cache", Value::Bool(prefix)),
        ("sharers", num(sharers as f64)),
        ("prefix_len", num(prefix_len as f64)),
        ("prefill_tokens_ran", num(prefill_ran as f64)),
        ("prefix_tokens_reused", num(eng.metrics.prefix_tokens_reused as f64)),
        ("prefix_hits", num(eng.metrics.prefix_hits as f64)),
        ("peak_logical_bytes", num(peak_logical as f64)),
        ("peak_physical_bytes", num(peak_physical as f64)),
        ("pages_in_use", num(eng.metrics.pages_in_use as f64)),
        ("decode_tok_p50_ms", num(eng.metrics.per_token.p(50.0) * 1e3)),
        ("wall_s", num(wall)),
    ])
}

fn prefix_section(quick: bool) -> Vec<Value> {
    let (sharers, prefix_len) = if quick { (8, 128) } else { (32, 512) };
    println!("# prefix reuse: {sharers} requests sharing a {prefix_len}-token system prompt");
    println!("# shared-prefix batch vs cold batch (same prompts, prefix cache off)\n");
    let rows = vec![prefix_run(false, sharers, prefix_len), prefix_run(true, sharers, prefix_len)];
    println!();
    rows
}

/// Tier probe: N requests sharing one long system prompt, served three
/// ways — cold (prefix index cleared before every request: full
/// re-prefill), resident (plain RAM prefix hit), and tier (every cached
/// page demoted to disk before each request, so the hit PROMOTES).  The
/// per-request wall time of the tier row IS the promotion latency the
/// ISSUE asks CI to track, next to the cold bound it must beat and the
/// resident floor it cannot.
fn tier_run(mode: &str, sharers: usize, prefix_len: usize) -> Value {
    let mut opts = EngineOpts::default();
    opts.prefill_chunk = 32; // multiple of engine_cfg group=16
    opts.prefix_cache = true;
    opts.policy.max_running = 64;
    opts.policy.prefill_per_step = 1;
    opts.admission.max_queue = 256;
    let mut eng = Engine::native_synthetic(engine_cfg(), 7, 6.0, opts);
    let dir = std::env::temp_dir()
        .join(format!("polarquant-tier-bench-{}-{mode}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    if mode == "tier" {
        eng.attach_tier(&TierOpts { dir: dir.clone(), max_bytes: u64::MAX, snapshot: false })
            .expect("attach tier");
    }
    let mut rng = Rng::new(19);
    let system: Vec<u32> = (0..prefix_len).map(|_| rng.below(128) as u32).collect();
    // warm request registers the shared prefix
    eng.submit(Request::greedy(0, system.clone(), 4)).unwrap();
    eng.run_to_completion().unwrap();
    let between = |eng: &mut Engine| match mode {
        "cold" => {
            eng.page_pool().clear_prefix_index();
        }
        "tier" => {
            eng.page_pool().demote_all();
        }
        _ => {}
    };
    between(&mut eng);
    let prefill0 = eng.metrics.prefill_tokens;
    let mut peak_physical = 0usize;
    let mut request_ms = Vec::with_capacity(sharers);
    for i in 0..sharers {
        let prompt: Vec<u32> = system
            .iter()
            .cloned()
            .chain((0..8).map(|_| rng.below(128) as u32))
            .collect();
        let t0 = std::time::Instant::now();
        eng.submit(Request::greedy(1 + i as u64, prompt, 8)).unwrap();
        while !eng.idle() {
            eng.step().unwrap();
            peak_physical = peak_physical.max(eng.cache_report().physical_bytes);
        }
        request_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        between(&mut eng);
    }
    let mean_ms = request_ms.iter().sum::<f64>() / sharers as f64;
    let prefill_ran = eng.metrics.prefill_tokens - prefill0;
    let pool = eng.page_pool();
    println!(
        "{mode:>8}: request mean {mean_ms:>8.3} ms, prefill {prefill_ran:>6} tok, tier hits {:>3} \
         (promoted {:>3}, demoted {:>3}), peak resident {:>9} B, {:>9} B on disk",
        pool.tier_hits(),
        pool.pages_promoted(),
        pool.pages_demoted(),
        peak_physical,
        pool.bytes_on_disk(),
    );
    let row = obj(vec![
        ("mode", json::s(mode)),
        ("sharers", num(sharers as f64)),
        ("prefix_len", num(prefix_len as f64)),
        ("request_mean_ms", num(mean_ms)),
        ("prefill_tokens_ran", num(prefill_ran as f64)),
        ("tier_hits", num(pool.tier_hits() as f64)),
        ("pages_promoted", num(pool.pages_promoted() as f64)),
        ("pages_demoted", num(pool.pages_demoted() as f64)),
        ("peak_physical_bytes", num(peak_physical as f64)),
        ("bytes_on_disk", num(pool.bytes_on_disk() as f64)),
    ]);
    let _ = std::fs::remove_dir_all(&dir);
    row
}

fn tier_section(quick: bool) -> Vec<Value> {
    let (sharers, prefix_len) = if quick { (6, 128) } else { (16, 512) };
    println!("# tier: {sharers} requests sharing a {prefix_len}-token system prompt");
    println!("# cold re-prefill vs resident prefix hit vs demoted-then-promoted (disk)\n");
    let rows = vec![
        tier_run("cold", sharers, prefix_len),
        tier_run("resident", sharers, prefix_len),
        tier_run("tier", sharers, prefix_len),
    ];
    println!();
    rows
}

/// Streaming probe: client-visible time-to-first-output and inter-token
/// latency, one-shot API vs streaming API over the SAME engine and
/// request mix.  One-shot clients hear nothing until the completion
/// lands, so their "TTFT" is the full request latency; streaming clients
/// see the first token the step it decodes — the latency win this
/// section tracks per commit, next to the ITL p50 the engine sustains.
fn streaming_run(stream: bool, batch: usize, prompt_len: usize, gen_len: usize) -> Value {
    let mut opts = EngineOpts::default();
    opts.prefill_chunk = 32;
    opts.policy.max_running = 64;
    opts.policy.prefill_per_step = 2;
    opts.admission.max_queue = 256;
    let mut eng = Engine::native_synthetic(engine_cfg(), 9, 6.0, opts);
    let mut rng = Rng::new(23);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..batch {
        let prompt: Vec<u32> = (0..prompt_len).map(|_| rng.below(128) as u32).collect();
        let req = Request::greedy(i as u64, prompt, gen_len);
        if stream {
            rxs.push(eng.submit_streaming(req));
        } else {
            eng.submit(req).unwrap();
        }
    }
    let mut first_out: Vec<f64> = Vec::with_capacity(batch);
    let mut last_tok: Vec<Option<f64>> = vec![None; batch];
    let mut client_itl: Vec<f64> = Vec::new();
    while !eng.idle() {
        let done = eng.step().unwrap();
        let now = t0.elapsed().as_secs_f64();
        if stream {
            for (i, rx) in rxs.iter().enumerate() {
                while let Ok(ev) = rx.try_recv() {
                    if matches!(ev, Event::Token { .. }) {
                        match last_tok[i] {
                            None => first_out.push(now),
                            Some(prev) => client_itl.push(now - prev),
                        }
                        last_tok[i] = Some(now);
                    }
                }
            }
        } else {
            for _ in &done {
                first_out.push(now); // one-shot: first output IS the reply
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let tok_s = eng.metrics.decode_tokens as f64 / wall;
    let ttfo_p50 = percentile(&first_out, 50.0) * 1e3;
    let engine_itl_p50 = eng.metrics.itl.p(50.0) * 1e3;
    let label = if stream { "streaming" } else { "one-shot " };
    let mut fields = vec![
        ("mode", json::s(if stream { "streaming" } else { "one_shot" })),
        ("batch", num(batch as f64)),
        ("prompt_len", num(prompt_len as f64)),
        ("gen_len", num(gen_len as f64)),
        ("first_output_p50_ms", num(ttfo_p50)),
        ("engine_ttft_p50_ms", num(eng.metrics.ttft.p(50.0) * 1e3)),
        ("engine_itl_p50_ms", num(engine_itl_p50)),
        ("decode_tok_s", num(tok_s)),
        ("wall_s", num(wall)),
    ];
    if stream {
        fields.push(("client_itl_p50_ms", num(percentile(&client_itl, 50.0) * 1e3)));
        println!(
            "{label}: first output p50 {ttfo_p50:>8.3} ms, client itl p50 {:>7.3} ms, \
             engine itl p50 {engine_itl_p50:>7.3} ms, {tok_s:>9.1} tok/s",
            percentile(&client_itl, 50.0) * 1e3,
        );
    } else {
        println!(
            "{label}: first output p50 {ttfo_p50:>8.3} ms (— full reply), \
             engine itl p50 {engine_itl_p50:>7.3} ms, {tok_s:>9.1} tok/s",
        );
    }
    obj(fields)
}

fn streaming_section(quick: bool) -> Vec<Value> {
    let (batch, prompt_len, gen_len) = if quick { (8, 64, 16) } else { (16, 256, 48) };
    println!("# streaming: client-visible TTFT + inter-token latency vs one-shot");
    println!("# {batch} requests, prompt {prompt_len}, gen {gen_len}, chunked prefill 32\n");
    let rows = vec![
        streaming_run(false, batch, prompt_len, gen_len),
        streaming_run(true, batch, prompt_len, gen_len),
    ];
    println!();
    rows
}

/// Mixed-tenant flood probe: the well-behaved "calm" tenant's ITL while
/// the "flood" tenant saturates the engine with long prompts.  Returns
/// (calm p50 ms, calm p99 ms, flood completions) so the section can
/// compare solo / fcfs / wfq on identical calm traffic.
fn tenant_run(
    sched: SchedMode,
    flooders: usize,
    flood_prompt: usize,
    calm_reqs: usize,
) -> (f64, f64, u64) {
    let mut opts = EngineOpts::default();
    opts.prefill_chunk = 32;
    opts.sched = sched;
    opts.policy.max_running = 8;
    opts.policy.prefill_per_step = 2;
    opts.admission.max_queue = 256;
    let mut eng = Engine::native_synthetic(engine_cfg(), 27, 6.0, opts);
    if sched == SchedMode::Wfq {
        let mut t = TenancyOpts::default();
        t.weights.insert("calm".to_string(), 4);
        t.weights.insert("flood".to_string(), 1);
        eng.set_tenancy(&t);
    }
    let mut rng = Rng::new(31);
    // the flood arrives first: under FCFS the calm tenant queues behind
    // every flooder; under WFQ the stride scheduler lets it through
    for i in 0..flooders {
        let prompt: Vec<u32> = (0..flood_prompt).map(|_| rng.below(128) as u32).collect();
        let mut r = Request::greedy(i as u64, prompt, 32);
        r.tenant = "flood".to_string();
        eng.submit(r).unwrap();
    }
    for i in 0..calm_reqs {
        let prompt: Vec<u32> = (0..32).map(|_| rng.below(128) as u32).collect();
        let mut r = Request::greedy(1000 + i as u64, prompt, 32);
        r.tenant = "calm".to_string();
        eng.submit(r).unwrap();
    }
    eng.run_to_completion().unwrap();
    let calm = &eng.metrics.tenants["calm"];
    (calm.itl.p(50.0) * 1e3, calm.itl.p(99.0) * 1e3, eng.metrics.tenants.get("flood").map_or(0, |t| t.finished))
}

fn multi_tenant_section(quick: bool) -> Vec<Value> {
    let (flooders, flood_prompt, calm_reqs) = if quick { (8, 128, 4) } else { (16, 512, 8) };
    println!("# multi-tenant: calm tenant's ITL under a {flooders}-request flood");
    println!("# solo baseline vs fcfs vs wfq (calm weight 4, flood weight 1)\n");
    let (solo_p50, solo_p99, _) = tenant_run(SchedMode::Fcfs, 0, flood_prompt, calm_reqs);
    let (fcfs_p50, fcfs_p99, fcfs_fin) = tenant_run(SchedMode::Fcfs, flooders, flood_prompt, calm_reqs);
    let (wfq_p50, wfq_p99, wfq_fin) = tenant_run(SchedMode::Wfq, flooders, flood_prompt, calm_reqs);
    // the PR's acceptance bar: fair scheduling holds the well-behaved
    // tenant's tail latency near its uncontended baseline under flood
    let within = wfq_p99 <= solo_p99 * 1.25;
    println!("    solo: calm itl p50 {solo_p50:>8.3} ms  p99 {solo_p99:>8.3} ms");
    println!("    fcfs: calm itl p50 {fcfs_p50:>8.3} ms  p99 {fcfs_p99:>8.3} ms");
    println!(
        "     wfq: calm itl p50 {wfq_p50:>8.3} ms  p99 {wfq_p99:>8.3} ms   [{}]",
        if within { "within 25% of solo" } else { "FAIL: > 1.25x solo p99" }
    );
    println!("    (flood still completes: fcfs {fcfs_fin}, wfq {wfq_fin})\n");
    vec![obj(vec![
        ("flooders", num(flooders as f64)),
        ("flood_prompt", num(flood_prompt as f64)),
        ("calm_reqs", num(calm_reqs as f64)),
        ("calm_weight", num(4.0)),
        ("solo_itl_p50_ms", num(solo_p50)),
        ("solo_itl_p99_ms", num(solo_p99)),
        ("fcfs_itl_p50_ms", num(fcfs_p50)),
        ("fcfs_itl_p99_ms", num(fcfs_p99)),
        ("wfq_itl_p50_ms", num(wfq_p50)),
        ("wfq_itl_p99_ms", num(wfq_p99)),
        ("flood_finished_fcfs", num(fcfs_fin as f64)),
        ("flood_finished_wfq", num(wfq_fin as f64)),
        ("wfq_within_25pct", Value::Bool(within)),
    ])]
}

/// Speculative-decoding probe: the same greedy request mix decoded with
/// `--speculate K` on a draft plane vs the k=0 baseline.  Output is
/// bit-identical BY CONTRACT (asserted here before timing is trusted);
/// the win shows up as decode-steps-per-token < 1.0 and the accepted-run
/// -length, alongside the TTFT/ITL the fewer iterations buy.
fn speculative_run(
    speculate: usize,
    draft: Option<(u32, u32)>,
    batch: usize,
    prompt_len: usize,
    gen_len: usize,
) -> (Vec<Vec<u32>>, Value) {
    let mut opts = EngineOpts::default();
    opts.policy.max_running = batch.max(32);
    opts.policy.prefill_per_step = batch;
    opts.admission.max_queue = batch.max(256);
    opts.speculate = speculate;
    opts.draft_bits = draft;
    let mut eng = Engine::native_synthetic(engine_cfg(), 37, 6.0, opts);
    let mut rng = Rng::new(41);
    let t0 = Instant::now();
    for i in 0..batch {
        let prompt: Vec<u32> = (0..prompt_len).map(|_| rng.below(128) as u32).collect();
        eng.submit(Request::greedy(i as u64, prompt, gen_len)).unwrap();
    }
    let mut done = eng.run_to_completion().unwrap();
    let wall = t0.elapsed().as_secs_f64();
    done.sort_by_key(|c| c.id);
    let tokens: Vec<Vec<u32>> = done.into_iter().map(|c| c.tokens).collect();
    let m = &eng.metrics;
    let steps_per_token = m.decode_steps as f64 / m.decode_tokens as f64;
    let label = match draft {
        None if speculate == 0 => "off      ".to_string(),
        None => format!("k={speculate} r2/t2"),
        Some((r, t)) => format!("k={speculate} r{r}/t{t}"),
    };
    println!(
        "{label:>9}: {:>6.3} steps/tok, run len {:>5.2}, accept {:>5.1}%, \
         itl p50 {:>7.3} ms, {:>9.1} tok/s",
        steps_per_token,
        m.speculative_run_length(),
        m.speculative_acceptance() * 100.0,
        m.itl.p(50.0) * 1e3,
        m.decode_tokens as f64 / wall,
    );
    let row = obj(vec![
        ("speculate", num(speculate as f64)),
        (
            "draft_bits",
            match draft {
                Some((r, t)) => json::s(&format!("{r},{t}")),
                None => json::s("halved"),
            },
        ),
        ("batch", num(batch as f64)),
        ("gen_len", num(gen_len as f64)),
        ("decode_steps", num(m.decode_steps as f64)),
        ("decode_tokens", num(m.decode_tokens as f64)),
        ("decode_steps_per_token", num(steps_per_token)),
        ("accepted_run_length", num(m.speculative_run_length())),
        ("acceptance_rate", num(m.speculative_acceptance())),
        ("speculative_rounds", num(m.speculative_rounds as f64)),
        ("ttft_p50_ms", num(m.ttft.p(50.0) * 1e3)),
        ("itl_p50_ms", num(m.itl.p(50.0) * 1e3)),
        ("decode_tok_s", num(m.decode_tokens as f64 / wall)),
        ("wall_s", num(wall)),
    ]);
    (tokens, row)
}

fn speculative_section(quick: bool) -> Vec<Value> {
    let (batch, prompt_len, gen_len) = if quick { (4, 24, 16) } else { (8, 48, 48) };
    println!("# speculative: self-drafted windows on the truncated code plane");
    println!("# {batch} greedy requests, prompt {prompt_len}, gen {gen_len}; output bit-identical by contract\n");
    // k=0 baseline, the halved default draft at k in {2,4}, and the
    // exact-width draft (r4/t4 on this cfg) where every proposal verifies
    // — the upper bound on what acceptance can buy
    let (baseline, row0) = speculative_run(0, None, batch, prompt_len, gen_len);
    let mut rows = vec![row0];
    for (k, draft) in [(2, None), (4, None), (2, Some((4, 4))), (4, Some((4, 4)))] {
        let (tokens, row) = speculative_run(k, draft, batch, prompt_len, gen_len);
        assert_eq!(tokens, baseline, "speculation (k={k}, {draft:?}) changed a greedy rollout");
        rows.push(row);
    }
    println!();
    rows
}

fn engine_section(quick: bool) -> Vec<Value> {
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8);
    let (prompt_len, gen_len) = if quick { (32, 6) } else { (64, 24) };
    let mut rows = Vec::new();
    println!("# engine: native decode tokens/s, pool ({workers} threads) vs inline");
    println!("# toy model (2L d64), prompt {prompt_len}, gen {gen_len}\n");
    for &b in &BATCHES {
        let inline_tok_s = engine_run(b, 1, prompt_len, gen_len);
        let pool_tok_s = engine_run(b, workers, prompt_len, gen_len);
        println!(
            "batch {b:>4}: inline {inline_tok_s:>9.1} tok/s   pool {pool_tok_s:>9.1} tok/s   ({:.2}x)",
            pool_tok_s / inline_tok_s
        );
        rows.push(obj(vec![
            ("batch", num(b as f64)),
            ("decode_workers", num(workers as f64)),
            ("inline_tok_s", num(inline_tok_s)),
            ("pool_tok_s", num(pool_tok_s)),
            ("pool_speedup", num(pool_tok_s / inline_tok_s)),
        ]));
    }
    println!();
    rows
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    // chunk size for the chunked-prefill section (CI passes this so the
    // JSON artifact tracks decode-stall regressions per commit)
    let chunk = args
        .iter()
        .position(|a| a == "--prefill-chunk")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let ctx = if quick { 512 } else { 2048 };
    let opts = BenchOpts {
        warmup: std::time::Duration::from_millis(if quick { 20 } else { 120 }),
        budget: std::time::Duration::from_millis(if quick { 150 } else { 600 }),
        min_iters: 3,
        max_iters: 100_000,
    };

    let kernel_rows = kernel_section(ctx, opts);
    let roofline_rows = roofline_section(ctx, opts);
    let engine_rows = engine_section(quick);
    let chunked_rows = chunked_section(quick, chunk);
    let prefix_rows = prefix_section(quick);
    let tier_rows = tier_section(quick);
    let streaming_rows = streaming_section(quick);
    let tenant_rows = multi_tenant_section(quick);
    let speculative_rows = speculative_section(quick);

    let report = obj(vec![
        ("bench", json::s("decode_batch")),
        ("quick", Value::Bool(quick)),
        (
            "geometry",
            obj(vec![
                ("d", num(D as f64)),
                ("hq", num(HQ as f64)),
                ("group", num(GROUP as f64)),
                ("ctx", num(ctx as f64)),
                ("spec", json::s("polar r4/t4 vs kivi-4, group 128")),
            ]),
        ),
        ("kernel", Value::Arr(kernel_rows)),
        ("roofline", Value::Arr(roofline_rows)),
        ("engine", Value::Arr(engine_rows)),
        ("chunked_prefill", Value::Arr(chunked_rows)),
        ("prefix_reuse", Value::Arr(prefix_rows)),
        ("tier", Value::Arr(tier_rows)),
        ("streaming", Value::Arr(streaming_rows)),
        ("multi_tenant", Value::Arr(tenant_rows)),
        ("speculative", Value::Arr(speculative_rows)),
    ]);
    let path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_decode_batch.json".to_string());
    std::fs::write(&path, json::write(&report)).expect("writing bench json");
    println!("# wrote {path}");
}
