//! Codec micro-benchmarks + design ablations (DESIGN.md calls these out):
//!
//!   * encode throughput per codec (tokens/s at d=128)
//!   * LUT ablation 1 — GQA basis sharing: scores_multi (one trig pass for
//!     4 query heads) vs 4 single-head passes
//!   * LUT ablation 2 — how much of KIVI's gap is *implementation*: the
//!     paper's dequant-then-multiply vs the algebraic "fold q into scales"
//!     shortcut (scores_folded)
//!   * bit-packing cost: packed vs unpacked code access in the QK loop

use polarquant::quant::kivi::{self, KiviQk, KiviSpec};
use polarquant::quant::polar::{self, PolarSpec};
use polarquant::quant::{int_n, zipcache, QkLut};
use polarquant::util::bench::{bench_fn, black_box, BenchOpts};
use polarquant::util::rng::Rng;

const D: usize = 128;
const GROUP: usize = 128;
const CTX: usize = 8192;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = BenchOpts {
        warmup: std::time::Duration::from_millis(if quick { 20 } else { 100 }),
        budget: std::time::Duration::from_millis(if quick { 120 } else { 500 }),
        min_iters: 3,
        max_iters: 1_000_000,
    };
    let mut rng = Rng::new(5);
    let keys = rng.normal_vec(CTX * D);
    let q: Vec<f32> = rng.normal_vec(D);

    println!("# encode throughput (ctx={CTX}, d={D})");
    let r = bench_fn("encode polar44", opts, || {
        black_box(polar::encode(&keys, D, &PolarSpec::new(4, 4, GROUP)))
    });
    println!("{r}  ({:.1} Mtok/s)", r.throughput(CTX as f64) / 1e6);
    let r = bench_fn("encode kivi4", opts, || {
        black_box(kivi::encode(&keys, D, &KiviSpec::new(4, GROUP)))
    });
    println!("{r}  ({:.1} Mtok/s)", r.throughput(CTX as f64) / 1e6);
    let r = bench_fn("encode int4", opts, || black_box(int_n::encode(&keys, D, 4)));
    println!("{r}  ({:.1} Mtok/s)", r.throughput(CTX as f64) / 1e6);
    let r = bench_fn("encode zipcache4", opts, || black_box(zipcache::encode(&keys, D, 4)));
    println!("{r}  ({:.1} Mtok/s)", r.throughput(CTX as f64) / 1e6);

    println!("\n# ablation: GQA basis sharing in the LUT kernel");
    let spec = PolarSpec::new(4, 4, GROUP);
    let enc = polar::encode(&keys, D, &spec);
    let qs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(D)).collect();
    let qrefs: Vec<&[f32]> = qs.iter().map(|v| v.as_slice()).collect();
    let mut lut = QkLut::new(spec, D, 4);
    let mut multi: Vec<Vec<f32>> = vec![Vec::new(); 4];
    let shared = bench_fn("lut 4 heads, shared basis", opts, || {
        lut.scores_multi(&qrefs, &enc, &mut multi);
        black_box(multi[0].len())
    });
    println!("{shared}");
    let mut single = Vec::new();
    let separate = bench_fn("lut 4 heads, separate", opts, || {
        for qh in &qs {
            lut.scores(qh, &enc, &mut single);
        }
        black_box(single.len())
    });
    println!("{separate}");
    println!(
        "  -> basis sharing saves {:.1}% of LUT time\n",
        100.0 * (1.0 - shared.mean_s / separate.mean_s)
    );

    println!("# ablation: KIVI implementation gap (dequant-then-dot vs folded)");
    let kspec = KiviSpec::new(4, GROUP);
    let kenc = kivi::encode(&keys, D, &kspec);
    let mut qk = KiviQk::new(kspec, D);
    let mut scores = Vec::new();
    let naive = bench_fn("kivi dequant-then-dot (paper baseline)", opts, || {
        qk.scores(&q, &kenc, &mut scores);
        black_box(scores[CTX - 1])
    });
    println!("{naive}");
    let folded = bench_fn("kivi folded scales (ablation)", opts, || {
        qk.scores_folded(&q, &kenc, &mut scores);
        black_box(scores[CTX - 1])
    });
    println!("{folded}");
    println!(
        "  -> folding recovers {:.1}% of KIVI's decode cost — part of the\n\
         \x20   LUT win is algorithmic (finite-state products), part is the\n\
         \x20   baseline's dequant materialization\n",
        100.0 * (1.0 - folded.mean_s / naive.mean_s)
    );

    println!("# bit-pack access cost (unpack one group, 4-bit x {} codes)", GROUP * D / 2);
    let g = &enc.groups[0];
    let mut buf = vec![0u8; GROUP * D / 2];
    let r = bench_fn("unpack 4-bit group", opts, || {
        g.theta_codes.unpack_into(&mut buf);
        black_box(buf[0])
    });
    println!("{r}  ({:.2} Gcodes/s)", r.throughput((GROUP * D / 2) as f64) / 1e9);
}
