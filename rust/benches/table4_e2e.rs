//! Table 4 (complement): end-to-end engine decode-step latency vs context
//! length — the serving-level view of the kernel numbers in
//! fig3_qk_latency.  Runs the NATIVE backend (shape-unconstrained) so the
//! sweep can reach long contexts; the PJRT path is exercised by
//! examples/serve_longcontext.rs and the engine integration tests.

use polarquant::coordinator::{Engine, EngineOpts, Request};
use polarquant::model::ModelConfig;
use polarquant::util::bench::{bench_fn, black_box, BenchOpts};
use polarquant::util::rng::Rng;

fn cfg(group: usize, r: u32, t: u32) -> ModelConfig {
    let mut c = ModelConfig::tiny();
    c.n_layers = 2;
    c.vocab = 128;
    c.d_model = 64;
    c.n_heads = 4;
    c.n_kv_heads = 2;
    c.head_dim = 32;
    c.ffn = 96;
    c.group = group;
    c.resid = if group >= 1 << 20 { 1 << 20 } else { 2 * group };
    c.r_bits = r;
    c.t_bits = t;
    c
}

fn decode_step_latency(label: &str, c: ModelConfig, ctx: usize, mut opts: BenchOpts) {
    let mut eng = Engine::native_synthetic(c, 3, 6.0, EngineOpts::default());
    let mut rng = Rng::new(1);
    let prompt: Vec<u32> = (0..ctx).map(|_| rng.below(128) as u32).collect();
    // build up the cache with a prefill, then time pure decode steps;
    // cap iterations so the cache grows <= ~12% during the measurement
    opts.max_iters = ((ctx / 8).max(16)) as u64;
    eng.submit(Request::greedy(1, prompt, 1_000_000)).unwrap();
    eng.step().unwrap(); // prefill + first token
    let r = bench_fn(&format!("{label} ctx={ctx}"), opts, || {
        black_box(eng.step().unwrap().len())
    });
    println!("{r}");
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = BenchOpts {
        warmup: std::time::Duration::from_millis(if quick { 20 } else { 80 }),
        budget: std::time::Duration::from_millis(if quick { 150 } else { 500 }),
        min_iters: 3,
        max_iters: 100_000,
    };
    println!("# Table 4 complement: engine decode-step latency vs context (native backend)\n");
    let ctxs: &[usize] = if quick { &[256, 1024] } else { &[256, 1024, 4096, 16384] };
    for &ctx in ctxs {
        decode_step_latency("Fp16 (never-quantized)", cfg(1 << 20, 4, 4), ctx, opts);
        decode_step_latency("PolarQuant44          ", cfg(64, 4, 4), ctx, opts);
        decode_step_latency("PolarQuant33          ", cfg(64, 3, 3), ctx, opts);
        println!();
    }
    println!("# shape: quantized decode overtakes fp as ctx grows (memory traffic");
    println!("# shrinks ~3.8x); absolute CPU numbers differ from the paper's A100.");
}
