//! Figure 3 / Table 4 (top): decode-time query–key kernel latency across
//! context lengths and batch sizes, Llama-3.1-8B attention geometry
//! (8 kv-heads x 4 query-heads each, head_dim 128, group 128).
//!
//! Methods (per the paper's comparison):
//!   Fp32        — dense dot products over fp keys (the fp16-torch row)
//!   KIVI-4/2    — dequantize-then-multiply over channel-wise codes
//!   Polar44/33  — the PolarQuant LUT kernel (this paper)
//!
//! One kv-head stream is measured (batch emulated by repeated query sets);
//! the full-model step is `streams = 8 * batch` times the per-stream cost,
//! reported alongside.  The reproduction target is the SHAPE: LUT decode
//! beats dequant-then-multiply everywhere and crosses fp as context grows
//! (paper: up to 2.7x vs KIVI, 1.6x vs fp16).

use polarquant::quant::kivi::{self, KiviQk, KiviSpec};
use polarquant::quant::polar::{self, PolarSpec};
use polarquant::quant::QkLut;
use polarquant::tensor::ops::dot;
use polarquant::util::bench::{bench_fn, black_box, BenchOpts, BenchResult};
use polarquant::util::rng::Rng;

const D: usize = 128;
const HQ: usize = 4; // query heads per kv head (32/8)
const GROUP: usize = 128;
const KV_HEADS: usize = 8;

struct Setup {
    keys: Vec<f32>,
    qs: Vec<Vec<f32>>, // HQ query heads
    polar44: polar::PolarEncoded,
    polar33: polar::PolarEncoded,
    kivi4: kivi::KiviEncoded,
    kivi2: kivi::KiviEncoded,
}

fn setup(ctx: usize, seed: u64) -> Setup {
    let mut rng = Rng::new(seed);
    let keys = rng.normal_vec(ctx * D);
    let qs: Vec<Vec<f32>> = (0..HQ).map(|_| rng.normal_vec(D)).collect();
    Setup {
        polar44: polar::encode(&keys, D, &PolarSpec::new(4, 4, GROUP)),
        polar33: polar::encode(&keys, D, &PolarSpec::new(3, 3, GROUP)),
        kivi4: kivi::encode(&keys, D, &KiviSpec::new(4, GROUP)),
        kivi2: kivi::encode(&keys, D, &KiviSpec::new(2, 32)),
        keys,
        qs,
    }
}

fn run_ctx(ctx: usize, batch: usize, opts: BenchOpts) -> Vec<BenchResult> {
    let s = setup(ctx, 99);
    let mut out = Vec::new();
    let qrefs: Vec<&[f32]> = s.qs.iter().map(|q| q.as_slice()).collect();

    // fp32 dense
    let keys = &s.keys;
    out.push(bench_fn(&format!("fp32      ctx={ctx} b={batch}"), opts, || {
        let mut acc = 0.0f32;
        for _ in 0..batch {
            for q in &s.qs {
                for n in 0..ctx {
                    acc += dot(q, &keys[n * D..(n + 1) * D]);
                }
            }
        }
        black_box(acc)
    }));

    // KIVI dequant-then-dot
    for (label, enc, spec) in [
        ("KIVI-4    ", &s.kivi4, KiviSpec::new(4, GROUP)),
        ("KIVI-2    ", &s.kivi2, KiviSpec::new(2, 32)),
    ] {
        let mut qk = KiviQk::new(spec, D);
        let mut scores = Vec::with_capacity(ctx);
        out.push(bench_fn(&format!("{label}ctx={ctx} b={batch}"), opts, || {
            let mut acc = 0.0f32;
            for _ in 0..batch {
                for q in &s.qs {
                    qk.scores(q, enc, &mut scores);
                    acc += scores[ctx - 1];
                }
            }
            black_box(acc)
        }));
    }

    // PolarQuant LUT (multi-head: basis shared across the HQ query heads)
    for (label, enc, spec) in [
        ("Polar44   ", &s.polar44, PolarSpec::new(4, 4, GROUP)),
        ("Polar33   ", &s.polar33, PolarSpec::new(3, 3, GROUP)),
    ] {
        let mut lut = QkLut::new(spec, D, HQ);
        let mut scores: Vec<Vec<f32>> = vec![Vec::with_capacity(ctx); HQ];
        out.push(bench_fn(&format!("{label}ctx={ctx} b={batch}"), opts, || {
            let mut acc = 0.0f32;
            for _ in 0..batch {
                lut.scores_multi(&qrefs, enc, &mut scores);
                acc += scores[0][ctx - 1];
            }
            black_box(acc)
        }));
    }
    out
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = BenchOpts {
        warmup: std::time::Duration::from_millis(if quick { 30 } else { 150 }),
        budget: std::time::Duration::from_millis(if quick { 150 } else { 700 }),
        min_iters: 3,
        max_iters: 100_000,
    };
    println!("# Figure 3 / Table 4 (top): QK kernel latency, one kv-head stream");
    println!("# geometry: d={D}, {HQ} q-heads/kv-head, group={GROUP}; full step = 8 kv-heads x batch\n");
    let ctxs: &[usize] = if quick { &[1024, 4096] } else { &[1024, 4096, 16384, 65536] };
    let batches: &[usize] = if quick { &[1] } else { &[1, 8] };
    let mut speedups = Vec::new();
    for &batch in batches {
        for &ctx in ctxs {
            let results = run_ctx(ctx, batch, opts);
            for r in &results {
                let full_step = r.mean_s * KV_HEADS as f64;
                println!("{r}   full-step {:.3}ms", full_step * 1e3);
            }
            let f = results[0].mean_s;
            let k4 = results[1].mean_s;
            let p44 = results[3].mean_s;
            let p33 = results[4].mean_s;
            println!(
                "  -> Polar44: {:.2}x vs fp32, {:.2}x vs KIVI-4 | Polar33: {:.2}x vs fp32\n",
                f / p44,
                k4 / p44,
                f / p33
            );
            speedups.push((ctx, batch, f / p44, k4 / p44));
        }
    }
    println!("# paper shape check: LUT beats dequant-then-multiply at every point;");
    println!("# speedup vs fp grows with context (paper: 1.6x fp16, 2.7x KIVI at 128K).");
    for (ctx, batch, vs_fp, vs_kivi) in speedups {
        println!("#   ctx={ctx:>6} b={batch}: vs_fp={vs_fp:.2}x vs_kivi={vs_kivi:.2}x");
    }
}
